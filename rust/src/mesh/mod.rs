//! Device mesh and model-parallel layouts (paper Table 1 + §3 "How blocks
//! align with model-parallel shards").
//!
//! A `Mesh` is a DP x TP grid of logical ranks. A `Layout` describes how a
//! parameter tensor is partitioned across the TP group (and, orthogonally,
//! how optimizer state is owned under ZeRO/FSDP). `block_grid` maps a layout
//! to the (r, c) block partition of the paper's block-spectral norm: a block
//! is *exactly* the shard a device owns, so block orthogonalization never
//! requires cross-device traffic.

use anyhow::{bail, Result};

/// DP x TP mesh of logical ranks. Rank id = dp_idx * tp + tp_idx.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mesh {
    pub dp: usize,
    pub tp: usize,
}

impl Mesh {
    pub fn new(dp: usize, tp: usize) -> Result<Mesh> {
        if dp == 0 || tp == 0 {
            bail!("mesh degrees must be positive, got dp={dp} tp={tp}");
        }
        Ok(Mesh { dp, tp })
    }

    pub fn world(&self) -> usize {
        self.dp * self.tp
    }

    pub fn dp_index(&self, rank: usize) -> usize {
        rank / self.tp
    }

    pub fn tp_index(&self, rank: usize) -> usize {
        rank % self.tp
    }

    pub fn rank(&self, dp_idx: usize, tp_idx: usize) -> usize {
        debug_assert!(dp_idx < self.dp && tp_idx < self.tp);
        dp_idx * self.tp + tp_idx
    }

    /// Ranks in the same TP group as `rank` (share one model replica).
    pub fn tp_group(&self, rank: usize) -> Vec<usize> {
        let d = self.dp_index(rank);
        (0..self.tp).map(|t| self.rank(d, t)).collect()
    }

    /// Ranks with the same TP index across DP groups (gradient all-reduce).
    pub fn dp_group(&self, rank: usize) -> Vec<usize> {
        let t = self.tp_index(rank);
        (0..self.dp).map(|d| self.rank(d, t)).collect()
    }
}

/// Optimizer-state residency across the DP group — the second, orthogonal
/// sharding axis of the paper's system setup ("eight-way tensor parallelism
/// and ZeRO optimizer state sharding"). Orthogonal to [`Layout`]: a layout
/// partitions a matrix across the TP group for *compute*; `StateSharding`
/// decides which DP rank *stores* the momentum for which rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StateSharding {
    /// Every DP rank holds the full momentum (baseline DDP): gradients are
    /// synchronized with one all-reduce, each rank redundantly updates a
    /// full momentum replica.
    #[default]
    Replicated,
    /// ZeRO-1: each DP rank owns only its `1/dp` row-slice of every
    /// momentum matrix. The gradient sync becomes a reduce-scatter (each
    /// rank receives exactly the mean-gradient rows it owns), the rank
    /// updates only its owned slice, and an all-gather reassembles the
    /// updated momentum before the TP orthogonalization phases. Momentum
    /// rows are disjoint across ranks, so the sharded update is
    /// *bit-identical* to the replicated one — only residency and the
    /// collective schedule change.
    Zero1,
    /// ZeRO-2: gradient *and* momentum row-slices end-to-end. Like
    /// `Zero1`, each DP rank owns its `1/dp` row-slice of every momentum
    /// matrix, but the gradient sync stops at the reduce-scatter — no
    /// rank stages a full synced matrix, and no all-gather of the
    /// updated momentum runs; the TP phase assembles each block directly
    /// from the slice-resident accumulators it intersects. Same math on
    /// the same disjoint rows, so trajectories stay *bit-identical* to
    /// `Zero1` and `Replicated`; what changes is residency (grad slices
    /// too) and per-rank wire bytes (`s·(dp-1)/dp`, reduce-scatter only).
    Zero2,
}

impl StateSharding {
    pub fn parse(s: &str) -> Result<StateSharding> {
        Ok(match s {
            "replicated" => StateSharding::Replicated,
            "zero1" => StateSharding::Zero1,
            "zero2" => StateSharding::Zero2,
            other => bail!(
                "unknown state sharding '{other}' (want \
                 replicated|zero1|zero2)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            StateSharding::Replicated => "replicated",
            StateSharding::Zero1 => "zero1",
            StateSharding::Zero2 => "zero2",
        }
    }

    /// Does this mode keep momentum as DP row-slices (ZeRO-1/2)?
    pub fn is_sliced(&self) -> bool {
        matches!(self, StateSharding::Zero1 | StateSharding::Zero2)
    }
}

/// DP communicator topology: how the gradient sync's collectives map
/// onto the physical mesh. Orthogonal to [`StateSharding`] (who *owns*
/// which momentum rows) — topology decides which wires those bytes
/// cross.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Topology {
    /// One flat DP group; every DP collective moves full-replica
    /// payloads (the historical accounting).
    #[default]
    FullReplica,
    /// dp-groups-per-shard: one DP sub-group per TP index. A TP-sharded
    /// matrix's gradient sync runs inside the group that owns that
    /// shard, so each collective is charged *shard-sized* bytes
    /// (`full / tp`), not full-replica payloads. Results are
    /// bit-identical — grouping reroutes the accounting and the
    /// sub-communicator plumbing, not the math.
    GroupedPerShard,
}

impl Topology {
    pub fn parse(s: &str) -> Result<Topology> {
        Ok(match s {
            "full-replica" => Topology::FullReplica,
            "grouped" => Topology::GroupedPerShard,
            other => bail!(
                "unknown topology '{other}' (want full-replica|grouped)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Topology::FullReplica => "full-replica",
            Topology::GroupedPerShard => "grouped",
        }
    }
}

/// How a matrix parameter is sharded across the TP group (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// No sharding (small params, ZeRO-1 replicated compute).
    Replicated,
    /// Megatron column-parallel: W (m x n) split into (m x n/c) shards.
    TpColumn,
    /// Megatron row-parallel: W split into (m/r x n) shards.
    TpRow,
    /// Hybrid 2D TP: r x c grid of (m/r x n/c) shards.
    TpGrid { rows: usize, cols: usize },
    /// FSDP2 / dim-0 sharding: contiguous slice along the first dim.
    Fsdp2Dim0,
    /// ZeRO layer-wise: each whole tensor owned by one rank; blocks never
    /// split the matrix, so block-orthogonalization == full for this param
    /// (the paper's §2.2 "ZeRO helps greatly" case).
    ZeroLayer,
}

impl Layout {
    pub fn parse(s: &str) -> Result<Layout> {
        Ok(match s {
            "replicated" => Layout::Replicated,
            "tp-column" => Layout::TpColumn,
            "tp-row" => Layout::TpRow,
            "fsdp2" => Layout::Fsdp2Dim0,
            "zero-layer" => Layout::ZeroLayer,
            other => {
                if let Some(dims) = other.strip_prefix("tp-grid:") {
                    let (r, c) = dims
                        .split_once('x')
                        .ok_or_else(|| anyhow::anyhow!("bad grid '{other}'"))?;
                    Layout::TpGrid { rows: r.parse()?, cols: c.parse()? }
                } else {
                    bail!("unknown layout '{other}'")
                }
            }
        })
    }

    /// Block partition (r, c) of an (m, n) matrix under this layout at TP
    /// degree `tp`. This is the (r, c) of the paper's block-spectral norm.
    pub fn block_grid(&self, tp: usize, m: usize, n: usize) -> (usize, usize) {
        match *self {
            Layout::Replicated | Layout::ZeroLayer => (1, 1),
            Layout::TpColumn => (1, tp.min(n)),
            Layout::TpRow => (tp.min(m), 1),
            Layout::TpGrid { rows, cols } => {
                assert_eq!(rows * cols, tp, "grid {rows}x{cols} != tp {tp}");
                (rows.min(m), cols.min(n))
            }
            Layout::Fsdp2Dim0 => (tp.min(m), 1),
        }
    }

    /// Does the optimizer need a gather across the TP group to see the full
    /// matrix? (Everything except replicated/ZeRO-layer.)
    pub fn needs_gather(&self) -> bool {
        !matches!(self, Layout::Replicated | Layout::ZeroLayer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_indexing() {
        let m = Mesh::new(2, 4).unwrap();
        assert_eq!(m.world(), 8);
        assert_eq!(m.rank(1, 2), 6);
        assert_eq!(m.dp_index(6), 1);
        assert_eq!(m.tp_index(6), 2);
        assert_eq!(m.tp_group(5), vec![4, 5, 6, 7]);
        assert_eq!(m.dp_group(5), vec![1, 5]);
        assert!(Mesh::new(0, 2).is_err());
    }

    #[test]
    fn groups_partition_world() {
        let m = Mesh::new(3, 2).unwrap();
        let mut seen = vec![false; m.world()];
        for d in 0..m.dp {
            for r in m.tp_group(m.rank(d, 0)) {
                assert!(!seen[r]);
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn block_grids() {
        assert_eq!(Layout::TpColumn.block_grid(4, 128, 512), (1, 4));
        assert_eq!(Layout::TpRow.block_grid(4, 128, 512), (4, 1));
        assert_eq!(
            Layout::TpGrid { rows: 2, cols: 4 }.block_grid(8, 64, 64),
            (2, 4)
        );
        assert_eq!(Layout::Fsdp2Dim0.block_grid(8, 128, 64), (8, 1));
        assert_eq!(Layout::ZeroLayer.block_grid(8, 128, 64), (1, 1));
        // degree larger than dim clamps
        assert_eq!(Layout::TpColumn.block_grid(16, 4, 8), (1, 8));
    }

    #[test]
    fn parse_state_sharding() {
        assert_eq!(
            StateSharding::parse("replicated").unwrap(),
            StateSharding::Replicated
        );
        assert_eq!(
            StateSharding::parse("zero1").unwrap(),
            StateSharding::Zero1
        );
        assert_eq!(
            StateSharding::parse("zero2").unwrap(),
            StateSharding::Zero2
        );
        assert!(StateSharding::parse("zero3").is_err());
        assert_eq!(StateSharding::default(), StateSharding::Replicated);
        assert_eq!(StateSharding::Zero1.name(), "zero1");
        assert_eq!(StateSharding::Zero2.name(), "zero2");
        assert!(StateSharding::Zero1.is_sliced());
        assert!(StateSharding::Zero2.is_sliced());
        assert!(!StateSharding::Replicated.is_sliced());
    }

    #[test]
    fn parse_topology() {
        assert_eq!(
            Topology::parse("full-replica").unwrap(),
            Topology::FullReplica
        );
        assert_eq!(
            Topology::parse("grouped").unwrap(),
            Topology::GroupedPerShard
        );
        assert!(Topology::parse("ring").is_err());
        assert_eq!(Topology::default(), Topology::FullReplica);
        assert_eq!(Topology::GroupedPerShard.name(), "grouped");
    }

    #[test]
    fn parse_layouts() {
        assert_eq!(Layout::parse("tp-column").unwrap(), Layout::TpColumn);
        assert_eq!(
            Layout::parse("tp-grid:2x4").unwrap(),
            Layout::TpGrid { rows: 2, cols: 4 }
        );
        assert!(Layout::parse("nope").is_err());
    }

    #[test]
    fn gather_requirements() {
        assert!(Layout::TpColumn.needs_gather());
        assert!(Layout::Fsdp2Dim0.needs_gather());
        assert!(!Layout::ZeroLayer.needs_gather());
        assert!(!Layout::Replicated.needs_gather());
    }
}
