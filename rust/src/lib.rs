//! # muonbp — MuonBP: Faster Muon via Block-Periodic Orthogonalization
//!
//! Full-system reproduction of the paper (Khaled et al., 2025): a
//! distributed-training framework whose Layer-3 coordinator implements the
//! paper's contribution — Muon with block-periodic orthogonalization across
//! model-parallel shards — on top of AOT-compiled JAX/Pallas compute
//! artifacts executed through the PJRT C API (`xla` crate).
//!
//! Architecture (see DESIGN.md):
//! - L1: Pallas Newton–Schulz kernel (python, build-time, `artifacts/ns_*`)
//! - L2: Llama-style transformer fwd/bwd (python, build-time,
//!   `artifacts/{train,eval}_*`)
//! - L3: this crate — mesh/sharding, simulated collectives with byte
//!   accounting, optimizer zoo (AdamW / Lion / Muon / BlockMuon / MuonBP /
//!   Dion), α–β cost model, theory (Theorem 2), trainer and the
//!   block-periodic coordinator.
//!
//! Python never runs on the step path: `make artifacts` once, then the rust
//! binary is self-contained.

// Index-heavy numeric kernels mirror the underlying shape algebra; iterator
// rewrites of those loops obscure the math without changing codegen.
#![allow(clippy::needless_range_loop)]

pub mod bench_util;
pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod linalg;
pub mod mesh;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod robust;
pub mod runtime;
pub mod shard;
pub mod tensor;
pub mod theory;
pub mod train;
pub mod utils;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
