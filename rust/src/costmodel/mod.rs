//! Analytic cost model: FLOP counts (paper §2.2/§3 formulas), α–β network
//! model, and the per-method throughput estimator behind Table 4 / Fig 3.
//!
//! The paper's testbed (A100 nodes) is unavailable; throughput claims are
//! *ratios* between methods, which derive from communication volume and
//! overlap structure — exactly what this model captures (DESIGN.md §1).

pub mod flops;
pub mod netmodel;
pub mod throughput;

pub use flops::{adam_flops, block_ns_flops, train_flops_per_step, ModelDims};
pub use netmodel::NetModel;
pub use throughput::{step_breakdown, throughput_tflops, Method, StepBreakdown};
