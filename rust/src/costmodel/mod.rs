//! Analytic cost model: FLOP counts (paper §2.2/§3 formulas), α–β network
//! model, the per-method throughput estimator behind Table 4 / Fig 3, and
//! the discrete-event cluster simulator.
//!
//! The paper's testbed (A100 nodes) is unavailable; throughput claims are
//! *ratios* between methods, which derive from communication volume and
//! overlap structure — exactly what this model captures (DESIGN.md §1).
//!
//! Two interchangeable pricers live behind the [`CostModel`] trait:
//! [`ClosedForm`] (the α–β formulas of [`netmodel`]) and [`Simulated`]
//! (event-level replay via [`sim`]). On uniform contention-free links
//! they agree to nanosecond rounding; the simulator additionally models
//! FIFO link contention, slab-pipeline overlap, and fail-slow faults.

pub mod api;
pub mod flops;
pub mod netmodel;
pub mod sim;
pub mod throughput;

pub use api::{ClosedForm, CostModel};
pub use flops::{adam_flops, block_ns_flops, train_flops_per_step, ModelDims};
pub use netmodel::NetModel;
pub use sim::Simulated;
pub use throughput::{
    step_breakdown, step_breakdown_with, throughput_tflops,
    throughput_tflops_with, Method, StepBreakdown,
};
