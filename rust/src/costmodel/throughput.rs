//! Per-method step-time / throughput estimator (Table 4, Fig 3 time axis).
//!
//! step_time(method) = T_compute + T_opt_comm + T_orth_compute, where
//! - T_compute: fwd+bwd FLOPs at an MFU-derated peak (identical for every
//!   optimizer — the paper's Adam column is the compute-only ceiling);
//! - T_opt_comm: the optimizer-specific collectives. Muon gathers+scatters
//!   every hidden matrix's momentum across the TP group each step; MuonBP
//!   pays that 1/P of the time; BlockMuon/Adam pay none; Dion moves
//!   O((m+n)r) low-rank factors (Appendix C);
//! - T_orth_compute: NS (or power-iteration) FLOPs at matmul efficiency,
//!   divided over the ranks that share the work (ZeRO layer-wise spreads
//!   matrices across the DP group; TP blocks split within the TP group).

use crate::comm::stats::CollectiveKind;
use crate::costmodel::api::{ClosedForm, CostModel};
use crate::costmodel::flops::{
    adam_flops, block_ns_flops, full_ns_flops, train_flops_per_step, ModelDims,
};
use crate::costmodel::netmodel::NetModel;

/// Optimizer methods compared in the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Adam,
    Muon,
    BlockMuon,
    /// Block-periodic with period P (P=1 degenerates to Muon).
    MuonBP { period: usize },
    /// Dion with low-rank factor r.
    Dion { rank: usize },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Adam => "Adam".into(),
            Method::Muon => "Muon".into(),
            Method::BlockMuon => "BlockMuon".into(),
            Method::MuonBP { period } => format!("MuonBP(P={period})"),
            Method::Dion { rank } => format!("Dion(r={rank})"),
        }
    }
}

/// Hardware preset for the throughput model.
#[derive(Debug, Clone, Copy)]
pub struct HwPreset {
    /// Peak dense bf16 TFLOP/s per GPU.
    pub peak_tflops: f64,
    /// Model FLOPs utilization of the fwd/bwd (calibrated to the paper's
    /// Adam column ~117-120 TFLOP/s on A100).
    pub mfu: f64,
    /// Efficiency of the (smaller) optimizer GEMMs.
    pub opt_eff: f64,
    /// Intra-node (TP) fabric.
    pub tp_net: NetModel,
    /// Inter-node (DP / ZeRO) fabric.
    pub dp_net: NetModel,
    /// Newton–Schulz iterations.
    pub ns_steps: usize,
}

impl HwPreset {
    /// Calibrated against the paper's Table 4: `mfu` reproduces the Adam
    /// (compute-only) column; `opt_eff` models fp32 Newton–Schulz GEMMs on
    /// strided shards with launch overhead (well below matmul peak — this
    /// is what makes Muon's 8B hit ~10%); the TP fabric uses effective
    /// all-gather bus bandwidth rather than nameplate NVLink.
    pub fn a100() -> HwPreset {
        HwPreset {
            peak_tflops: 312.0,
            mfu: 0.385,
            opt_eff: 0.18,
            tp_net: NetModel { alpha: 6e-6, beta_bw: 120e9 },
            dp_net: NetModel::ib_hdr(),
            ns_steps: 5,
        }
    }
}

/// Per-step time decomposition in seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepBreakdown {
    pub compute: f64,
    pub opt_comm: f64,
    pub orth_compute: f64,
}

impl StepBreakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.opt_comm + self.orth_compute
    }
}

/// Optimizer-specific TP communication for one *full* orthogonalization
/// pass: gather momentum shards + scatter updates for every hidden matrix.
fn full_orth_comm_time(
    dims: &ModelDims,
    cost: &dyn CostModel,
) -> f64 {
    let tp = dims.tp;
    if tp <= 1 {
        return 0.0;
    }
    let mut t = 0.0;
    for (m, n) in dims.all_matrix_shapes() {
        let bytes = m * n * 4;
        t += cost.collective_time(CollectiveKind::Gather, bytes, tp);
        t += cost.collective_time(CollectiveKind::Scatter, bytes, tp);
    }
    t
}

/// Step-time decomposition for a method on a model preset, pricing the
/// TP-fabric optimizer collectives through `cost` (closed-form α–β or
/// the discrete-event simulator — `--costmodel {closed-form,sim}`).
pub fn step_breakdown_with(
    dims: &ModelDims,
    method: Method,
    hw: &HwPreset,
    cost: &dyn CostModel,
) -> StepBreakdown {
    let world = dims.world() as f64;
    let effective = hw.peak_tflops * 1e12 * hw.mfu;
    let compute = train_flops_per_step(dims) / (effective * world);
    let opt_peak = hw.peak_tflops * 1e12 * hw.opt_eff;

    // TP block grid used by block steps: column-split (Megatron default).
    let grid = |_m: usize, _n: usize| (1usize, dims.tp);

    let (opt_comm, orth_flops) = match method {
        Method::Adam => (0.0, adam_flops(dims.n_params())),
        Method::Muon => {
            (full_orth_comm_time(dims, cost), full_ns_flops(dims, hw.ns_steps))
        }
        Method::BlockMuon => {
            // Block NS splits within the TP group too: each rank
            // orthogonalizes its own shard -> divide by tp as well.
            (0.0, block_ns_flops(dims, grid, hw.ns_steps) / dims.tp as f64)
        }
        Method::MuonBP { period } => {
            let p = period.max(1) as f64;
            let comm = full_orth_comm_time(dims, cost) / p;
            let flops = full_ns_flops(dims, hw.ns_steps) / p
                + (1.0 - 1.0 / p)
                    * block_ns_flops(dims, grid, hw.ns_steps)
                    / dims.tp as f64;
            (comm, flops)
        }
        Method::Dion { rank } => {
            // Appendix C: low-rank factors O((m+n)r) per matrix over the TP
            // fabric; compute O(mnr + mr² + r³ + mn) per matrix.
            let mut comm = 0.0;
            let mut flops = 0.0;
            for (m, n) in dims.all_matrix_shapes() {
                let bytes = (m + n) * rank * 4;
                comm += cost.collective_time(
                    CollectiveKind::AllGather,
                    bytes,
                    dims.tp,
                ) + cost.collective_time(
                    CollectiveKind::AllGather,
                    rank * rank * 4,
                    dims.tp,
                );
                let (mf, nf, rf) = (m as f64, n as f64, rank as f64);
                flops +=
                    2.0 * (mf * nf * rf * 3.0 + mf * rf * rf + rf.powi(3))
                        + mf * nf;
            }
            (comm, flops)
        }
    };

    // ZeRO layer-wise sharding spreads the orthogonalization work across
    // the DP group (paper §2.2: "apply orthogonalization layerwise in
    // parallel"); within a TP group block work is already per-rank.
    let orth_compute = orth_flops / (opt_peak * dims.dp as f64);
    StepBreakdown { compute, opt_comm, orth_compute }
}

/// [`step_breakdown_with`] priced by the closed-form α–β model on the
/// preset's TP fabric (the historical default).
pub fn step_breakdown(
    dims: &ModelDims,
    method: Method,
    hw: &HwPreset,
) -> StepBreakdown {
    step_breakdown_with(dims, method, hw, &ClosedForm(hw.tp_net))
}

/// [`throughput_tflops`] with an explicit [`CostModel`] pricing the
/// optimizer collectives.
pub fn throughput_tflops_with(
    dims: &ModelDims,
    method: Method,
    hw: &HwPreset,
    cost: &dyn CostModel,
) -> f64 {
    let b = step_breakdown_with(dims, method, hw, cost);
    train_flops_per_step(dims) / (b.total() * dims.world() as f64) / 1e12
}

/// Average realized throughput in TFLOP/s/GPU (the paper's Table 4 metric:
/// model FLOPs divided by wall time and GPU count).
pub fn throughput_tflops(
    dims: &ModelDims,
    method: Method,
    hw: &HwPreset,
) -> f64 {
    throughput_tflops_with(dims, method, hw, &ClosedForm(hw.tp_net))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwPreset {
        HwPreset::a100()
    }

    #[test]
    fn adam_is_fastest_muon_slowest() {
        for dims in
            [ModelDims::paper_960m(), ModelDims::paper_1_2b(), ModelDims::paper_8b()]
        {
            let adam = throughput_tflops(&dims, Method::Adam, &hw());
            let muon = throughput_tflops(&dims, Method::Muon, &hw());
            let block = throughput_tflops(&dims, Method::BlockMuon, &hw());
            let bp =
                throughput_tflops(&dims, Method::MuonBP { period: 5 }, &hw());
            assert!(adam > block, "{}: adam {adam} block {block}", dims.name);
            assert!(block > bp, "{}: block {block} bp {bp}", dims.name);
            assert!(bp > muon, "{}: bp {bp} muon {muon}", dims.name);
        }
    }

    #[test]
    fn muonbp_period_1_equals_muon() {
        let dims = ModelDims::paper_8b();
        let muon = step_breakdown(&dims, Method::Muon, &hw());
        let bp1 = step_breakdown(&dims, Method::MuonBP { period: 1 }, &hw());
        assert!((muon.total() - bp1.total()).abs() < 1e-9);
    }

    #[test]
    fn muonbp_approaches_blockmuon_as_p_grows() {
        let dims = ModelDims::paper_8b();
        let block = step_breakdown(&dims, Method::BlockMuon, &hw()).total();
        let bp = step_breakdown(&dims, Method::MuonBP { period: 1000 }, &hw())
            .total();
        assert!((bp - block).abs() / block < 0.01, "{bp} vs {block}");
    }

    #[test]
    fn gap_grows_with_scale() {
        // The paper's central throughput observation: Muon's relative loss
        // to Adam grows from ~4% (960M single node) to ~10% (8B, TP=8).
        let small = ModelDims::paper_960m();
        let big = ModelDims::paper_8b();
        let rel = |d: &ModelDims| {
            let adam = throughput_tflops(d, Method::Adam, &hw());
            let muon = throughput_tflops(d, Method::Muon, &hw());
            (adam - muon) / adam
        };
        assert!(rel(&big) > rel(&small), "{} vs {}", rel(&big), rel(&small));
    }

    #[test]
    fn muonbp_8b_recovers_most_of_gap() {
        // Paper: ~8% throughput increase for MuonBP vs Muon at 8B.
        let dims = ModelDims::paper_8b();
        let muon = throughput_tflops(&dims, Method::Muon, &hw());
        let bp = throughput_tflops(&dims, Method::MuonBP { period: 5 }, &hw());
        let gain = (bp - muon) / muon;
        assert!(gain > 0.03 && gain < 0.20, "gain {gain}");
    }

    #[test]
    fn throughput_in_plausible_a100_range() {
        let dims = ModelDims::paper_1_2b();
        let adam = throughput_tflops(&dims, Method::Adam, &hw());
        assert!(adam > 90.0 && adam < 140.0, "{adam}");
    }

    #[test]
    fn simulated_cost_model_tracks_the_closed_form() {
        // Gather/Scatter differ legitimately between the two pricers (the
        // sim's root-rooted transfers pay latency once, the closed form
        // charges (n-1)·α), so this pins scale agreement and method
        // ordering rather than exact equality.
        use crate::costmodel::Simulated;
        let hw = hw();
        let sim = Simulated::uniform(hw.tp_net);
        let cf = ClosedForm(hw.tp_net);
        let dims = ModelDims::paper_8b();
        for method in
            [Method::Muon, Method::MuonBP { period: 5 }, Method::Adam]
        {
            let s = step_breakdown_with(&dims, method, &hw, &sim);
            let c = step_breakdown_with(&dims, method, &hw, &cf);
            // Compute / orth columns don't touch the cost model at all.
            assert_eq!(s.compute, c.compute);
            assert_eq!(s.orth_compute, c.orth_compute);
            assert!(
                s.opt_comm <= c.opt_comm * 1.5 + 1e-12
                    && c.opt_comm <= s.opt_comm * 3.0 + 1e-12,
                "{}: sim {} vs cf {}",
                method.name(),
                s.opt_comm,
                c.opt_comm
            );
        }
        let muon = throughput_tflops_with(&dims, Method::Muon, &hw, &sim);
        let bp = throughput_tflops_with(
            &dims,
            Method::MuonBP { period: 5 },
            &hw,
            &sim,
        );
        let adam = throughput_tflops_with(&dims, Method::Adam, &hw, &sim);
        assert!(adam > bp && bp > muon, "{adam} {bp} {muon}");
    }
}
