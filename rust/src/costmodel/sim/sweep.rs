//! Scale sweep: project MuonBP step time across tp × dp × period ×
//! sharding grids by replaying each cell through the discrete-event
//! simulator, with a closed-form α–β column for cross-checking.
//!
//! `muonbp sim --sim-sweep` runs [`run_sweep`] on
//! [`SweepCfg::paper_8b_default`] and writes the JSON artifact to
//! `results/SIM_projection.json` (schema `muonbp.sim_projection.v1`).
//! The default grid reaches dp = 1024 — the big cells replay a few
//! million ring transfers each, so run the sweep in `--release`
//! (minutes, not hours).

use std::collections::BTreeMap;

use super::schedule::{
    ComputeModel, FabricLinks, ScheduleCfg, SimFaults, StepSchedule,
};
use crate::comm::stats::CollectiveKind;
use crate::costmodel::api::{ClosedForm, CostModel};
use crate::costmodel::flops::{train_flops_per_step, ModelDims};
use crate::costmodel::throughput::HwPreset;
use crate::mesh::{Layout, StateSharding, Topology};
use crate::utils::json::Json;

/// The sweep grid and fixed per-cell parameters.
#[derive(Debug, Clone)]
pub struct SweepCfg {
    pub dims: ModelDims,
    pub tp_list: Vec<usize>,
    pub dp_list: Vec<usize>,
    pub periods: Vec<usize>,
    pub shardings: Vec<StateSharding>,
    pub hw: HwPreset,
    /// DP-sync slab granularity per cell (2 keeps the dp=1024 cells
    /// tractable while still exercising the overlap pipeline).
    pub n_slabs: usize,
    pub chunk_bytes: usize,
}

impl SweepCfg {
    /// The acceptance grid: 8B model, tp ∈ {1, 8}, dp up to 1024,
    /// periods {1, 4, 16}, all three sharding modes, A100 fabrics.
    pub fn paper_8b_default() -> SweepCfg {
        SweepCfg {
            dims: ModelDims::paper_8b(),
            tp_list: vec![1, 8],
            dp_list: vec![1, 8, 64, 256, 1024],
            periods: vec![1, 4, 16],
            shardings: vec![
                StateSharding::Replicated,
                StateSharding::Zero1,
                StateSharding::Zero2,
            ],
            hw: HwPreset::a100(),
            n_slabs: 2,
            chunk_bytes: 1 << 20,
        }
    }
}

/// Closed-form analog of the sim's per-step optimizer cost, through the
/// [`CostModel`] trait: DP sync priced by `grad_sync_time`, the full
/// step adding TP gather/scatter + full NS, the block step overlapping
/// sync with block NS via `overlapped_step_time`.
fn closed_form_avg(
    cost: &dyn CostModel,
    hw: &HwPreset,
    sched: &StepSchedule,
    shapes: &[(usize, usize)],
    full_ns_secs: f64,
    block_ns_secs: f64,
) -> f64 {
    let cfg = sched.cfg;
    let sync = cost.grad_sync_time(
        cfg.sharding,
        sched.sync_bytes as usize,
        cfg.dp,
    );
    let mut tp_comm = 0.0;
    if cfg.tp > 1 {
        for &(m, n) in shapes {
            let bytes = m * n * 4;
            tp_comm += hw
                .tp_net
                .collective_time(CollectiveKind::Gather, bytes, cfg.tp);
            tp_comm += hw
                .tp_net
                .collective_time(CollectiveKind::Scatter, bytes, cfg.tp);
        }
    }
    let full = sync + tp_comm + full_ns_secs;
    let block = cost
        .overlapped_step_time(sync, block_ns_secs, cfg.n_slabs)
        .overlapped;
    let p = cfg.period.max(1) as f64;
    (full + (p - 1.0) * block) / p
}

/// Run the sweep; returns the `muonbp.sim_projection.v1` artifact.
pub fn run_sweep(cfg: &SweepCfg) -> anyhow::Result<Json> {
    let hw = &cfg.hw;
    let cm = ComputeModel {
        opt_flops_per_sec: hw.peak_tflops * 1e12 * hw.opt_eff,
        ns_steps: hw.ns_steps,
    };
    let links = FabricLinks::from_nets(hw.dp_net, hw.tp_net);
    let closed: ClosedForm = ClosedForm(hw.dp_net);
    let shapes = cfg.dims.all_matrix_shapes();
    let faults = SimFaults::default();

    // First pass: simulate every cell.
    struct Cell {
        tp: usize,
        dp: usize,
        period: usize,
        sharding: StateSharding,
        full_secs: f64,
        block_secs: f64,
        opt_secs: f64,
        cf_opt_secs: f64,
        train_secs: f64,
        step_secs: f64,
        tflops: f64,
    }
    let mut cells: Vec<Cell> = Vec::new();
    // (tp, dp, sharding) -> period-1 step time, the Muon baseline.
    let mut muon_step: BTreeMap<(usize, usize, &'static str), f64> =
        BTreeMap::new();
    for &tp in &cfg.tp_list {
        for &dp in &cfg.dp_list {
            let mut dims = cfg.dims.clone();
            dims.dp = dp;
            dims.tp = tp;
            let world = (dp * tp) as f64;
            let train_secs = train_flops_per_step(&dims)
                / (hw.peak_tflops * 1e12 * hw.mfu * world);
            for &sharding in &cfg.shardings {
                for &period in &cfg.periods {
                    let scfg = ScheduleCfg {
                        dp,
                        tp,
                        layout: Layout::TpColumn,
                        sharding,
                        topology: Topology::FullReplica,
                        period,
                        n_slabs: cfg.n_slabs,
                        overlap: true,
                        chunk_bytes: cfg.chunk_bytes,
                    };
                    let sched = StepSchedule::new(scfg, &shapes, &cm)?;
                    let t = sched.avg_step(links, &faults);
                    let full_ns_secs: f64 = sched
                        .full_ns
                        .iter()
                        .map(|&ns| ns as f64 / 1e9)
                        .sum();
                    let block_ns_secs = sched.block_ns_total as f64 / 1e9;
                    let cf = closed_form_avg(
                        &closed,
                        hw,
                        &sched,
                        &shapes,
                        full_ns_secs,
                        block_ns_secs,
                    );
                    let step_secs = train_secs + t.avg_secs;
                    let tflops = train_flops_per_step(&dims)
                        / (step_secs * world)
                        / 1e12;
                    if period == 1 {
                        muon_step
                            .insert((tp, dp, sharding.name()), step_secs);
                    }
                    cells.push(Cell {
                        tp,
                        dp,
                        period,
                        sharding,
                        full_secs: t.full_secs,
                        block_secs: t.block_secs,
                        opt_secs: t.avg_secs,
                        cf_opt_secs: cf,
                        train_secs,
                        step_secs,
                        tflops,
                    });
                }
            }
        }
    }

    // Second pass: join the per-(tp, dp, sharding) Muon (P=1) baseline.
    let cell_json: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut kv = vec![
                ("tp", Json::num(c.tp as f64)),
                ("dp", Json::num(c.dp as f64)),
                ("period", Json::num(c.period as f64)),
                ("sharding", Json::str(c.sharding.name())),
                ("sim_full_step_secs", Json::num(c.full_secs)),
                ("sim_block_step_secs", Json::num(c.block_secs)),
                ("sim_opt_secs", Json::num(c.opt_secs)),
                ("closed_form_opt_secs", Json::num(c.cf_opt_secs)),
                ("train_secs", Json::num(c.train_secs)),
                ("step_secs", Json::num(c.step_secs)),
                ("tflops_per_gpu", Json::num(c.tflops)),
            ];
            if let Some(&base) =
                muon_step.get(&(c.tp, c.dp, c.sharding.name()))
            {
                if base > 0.0 {
                    kv.push((
                        "speedup_vs_muon_pct",
                        Json::num((base / c.step_secs - 1.0) * 100.0),
                    ));
                }
            }
            Json::obj(kv)
        })
        .collect();

    Ok(Json::obj(vec![
        ("schema", Json::str("muonbp.sim_projection.v1")),
        ("model", Json::str(&cfg.dims.name)),
        (
            "hw",
            Json::obj(vec![
                ("peak_tflops", Json::num(hw.peak_tflops)),
                ("mfu", Json::num(hw.mfu)),
                ("opt_eff", Json::num(hw.opt_eff)),
                ("dp_alpha", Json::num(hw.dp_net.alpha)),
                ("dp_beta_bw", Json::num(hw.dp_net.beta_bw)),
                ("tp_alpha", Json::num(hw.tp_net.alpha)),
                ("tp_beta_bw", Json::num(hw.tp_net.beta_bw)),
                ("ns_steps", Json::num(hw.ns_steps as f64)),
            ]),
        ),
        (
            "axes",
            Json::obj(vec![
                (
                    "tp",
                    Json::Arr(
                        cfg.tp_list
                            .iter()
                            .map(|&x| Json::num(x as f64))
                            .collect(),
                    ),
                ),
                (
                    "dp",
                    Json::Arr(
                        cfg.dp_list
                            .iter()
                            .map(|&x| Json::num(x as f64))
                            .collect(),
                    ),
                ),
                (
                    "period",
                    Json::Arr(
                        cfg.periods
                            .iter()
                            .map(|&x| Json::num(x as f64))
                            .collect(),
                    ),
                ),
                (
                    "sharding",
                    Json::Arr(
                        cfg.shardings
                            .iter()
                            .map(|s| Json::str(s.name()))
                            .collect(),
                    ),
                ),
            ]),
        ),
        ("n_slabs", Json::num(cfg.n_slabs as f64)),
        ("chunk_bytes", Json::num(cfg.chunk_bytes as f64)),
        ("cells", Json::Arr(cell_json)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A small grid that exercises every code path in seconds.
    fn small() -> SweepCfg {
        let mut dims = ModelDims::paper_160m();
        dims.n_layers = 2;
        SweepCfg {
            dims,
            tp_list: vec![1, 2],
            dp_list: vec![1, 4],
            periods: vec![1, 4],
            shardings: vec![
                StateSharding::Replicated,
                StateSharding::Zero2,
            ],
            hw: HwPreset::a100(),
            n_slabs: 2,
            chunk_bytes: 1 << 20,
        }
    }

    #[test]
    fn sweep_covers_the_grid_and_joins_the_baseline() {
        let j = run_sweep(&small()).unwrap();
        assert_eq!(
            j.req("schema").unwrap().as_str().unwrap(),
            "muonbp.sim_projection.v1"
        );
        let cells = j.req("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2);
        for c in cells {
            let opt = c.req("sim_opt_secs").unwrap().as_f64().unwrap();
            let step = c.req("step_secs").unwrap().as_f64().unwrap();
            let train = c.req("train_secs").unwrap().as_f64().unwrap();
            assert!(opt > 0.0 && step > train, "degenerate cell {c:?}");
            // Every cell has a P=1 sibling, so the join always lands.
            let sp = c
                .req("speedup_vs_muon_pct")
                .unwrap()
                .as_f64()
                .unwrap();
            let period = c.req("period").unwrap().as_usize().unwrap();
            let tp = c.req("tp").unwrap().as_usize().unwrap();
            let dp = c.req("dp").unwrap().as_usize().unwrap();
            if period == 1 {
                assert!(sp.abs() < 1e-9, "P=1 speedup {sp}");
            } else if dp == 1 && tp == 1 {
                // Single rank: nothing to skip, P is a no-op (up to ns
                // rounding of the per-matrix compute segments).
                assert!(sp.abs() < 1e-3, "1x1 speedup {sp}");
            } else {
                assert!(sp > 0.0, "P={period} speedup {sp} !> 0");
            }
        }
    }

    #[test]
    fn closed_form_column_tracks_the_sim() {
        // Not an equivalence claim (the full step's gather/scatter and
        // overlap details differ) — but the two columns must agree on
        // scale, or the calibration story is broken.
        let j = run_sweep(&small()).unwrap();
        for c in j.req("cells").unwrap().as_arr().unwrap() {
            let sim = c.req("sim_opt_secs").unwrap().as_f64().unwrap();
            let cf =
                c.req("closed_form_opt_secs").unwrap().as_f64().unwrap();
            assert!(
                sim < cf * 3.0 && cf < sim * 3.0,
                "sim {sim} vs closed-form {cf} disagree on scale"
            );
        }
    }
}
