//! Fit α–β link parameters from a recorded [`CommReport`].
//!
//! Every DP-group ledger entry is one observation: over `calls`
//! collectives of mean payload `bytes / calls` at group size `ranks`,
//! the ring-algorithm analysis (the same table as
//! [`NetModel::collective_time`]) gives total latency steps `S` and
//! total wire bytes `W`, and the report gives total measured seconds
//! `T` (falling back to the modeled seconds when the run recorded
//! untimed). The model `T = α·S + W/β` is linear in `(α, 1/β)`, so the
//! fit is a 2×2 least-squares normal-equation solve — degenerate
//! designs (all-latency, all-bandwidth, or a single effective
//! direction) fall back to the corresponding one-parameter fit.
//!
//! TP-group entries are excluded: they ride a different fabric
//! (NVLink vs the DP InfiniBand plane), so mixing them would fit one
//! α–β to two links.

use crate::comm::report::CommReport;
use crate::comm::stats::CollectiveKind;
use crate::costmodel::netmodel::NetModel;

/// `(latency steps, wire bytes)` for one collective call of `bytes`
/// logical payload over `n` ranks — the ring table
/// [`NetModel::collective_time`] charges.
fn design_row(kind: CollectiveKind, bytes: f64, n: usize) -> (f64, f64) {
    if n <= 1 {
        return (0.0, 0.0);
    }
    let s = bytes;
    let nf = n as f64;
    match kind {
        CollectiveKind::Barrier => (nf - 1.0, 0.0),
        CollectiveKind::AllReduce => {
            (2.0 * (nf - 1.0), 2.0 * s * (nf - 1.0) / nf)
        }
        CollectiveKind::AllGather
        | CollectiveKind::ReduceScatter
        | CollectiveKind::Gather
        | CollectiveKind::Scatter
        | CollectiveKind::AllToAll => ((nf - 1.0), s * (nf - 1.0) / nf),
        CollectiveKind::Broadcast => (nf.log2().ceil(), s),
    }
}

/// Fit a [`NetModel`] for the DP fabric from `report`'s DP-group
/// ledgers (`"dp"` and the grouped `"shard N"` sub-groups; `"tp"` is a
/// different fabric and is skipped). Errors if the report holds no
/// usable DP observations.
pub fn calibrate(report: &CommReport) -> anyhow::Result<NetModel> {
    // (steps, wire_bytes, secs) per observation.
    let mut rows: Vec<(f64, f64, f64)> = Vec::new();
    for g in &report.groups {
        if g.name == "tp" {
            continue;
        }
        for e in &g.entries {
            if e.calls == 0 {
                continue;
            }
            let calls = e.calls as f64;
            let mean_bytes = e.bytes as f64 / calls;
            let (s1, w1) = design_row(e.kind, mean_bytes, g.ranks);
            let t = if e.measured_secs > 0.0 {
                e.measured_secs
            } else {
                e.modeled_secs
            };
            if s1 <= 0.0 && w1 <= 0.0 {
                continue; // n <= 1: the call was free, nothing to fit
            }
            rows.push((s1 * calls, w1 * calls, t));
        }
    }
    anyhow::ensure!(
        !rows.is_empty(),
        "calibrate: report has no DP-group collective calls to fit"
    );

    // Normal equations for T = α·S + inv·W, unknowns (α, inv = 1/β).
    let (mut ss, mut sw, mut ww, mut st, mut wt) =
        (0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for &(s, w, t) in &rows {
        ss += s * s;
        sw += s * w;
        ww += w * w;
        st += s * t;
        wt += w * t;
    }
    let det = ss * ww - sw * sw;
    let (alpha, inv_bw) = if det.abs() > 1e-9 * ss.max(1e-30) * ww.max(1e-30)
    {
        (
            (st * ww - wt * sw) / det,
            (wt * ss - st * sw) / det,
        )
    } else if ww > 0.0 {
        // Rank-deficient design with bandwidth signal (e.g. one
        // collective kind at one size): attribute everything to β.
        (0.0, wt / ww)
    } else {
        // Pure-latency traffic (barriers only): fit α alone.
        (st / ss, 0.0)
    };
    let alpha = alpha.max(0.0);
    let beta_bw = if inv_bw > 0.0 { 1.0 / inv_bw } else { f64::INFINITY };
    Ok(NetModel { alpha, beta_bw })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::report::{
        CommEntry, GroupReport, OverlapReport,
    };

    fn report_from(groups: Vec<GroupReport>) -> CommReport {
        CommReport {
            optimizer: "test".to_string(),
            schedule: "phased-barrier".to_string(),
            dp: 8,
            tp: 2,
            sharding: "replicated".to_string(),
            groups,
            overlap: OverlapReport {
                comm_secs: 0.0,
                compute_secs: 0.0,
                slab_stride: 1,
                serial_secs: 0.0,
                overlapped_secs: 0.0,
                bubble_frac: 0.0,
            },
        }
    }

    /// Synthesize a DP ledger from a known NetModel and check the fit
    /// recovers it.
    fn entry(
        net: &NetModel,
        kind: CollectiveKind,
        bytes: usize,
        calls: u64,
        n: usize,
    ) -> CommEntry {
        let t = net.collective_time(kind, bytes, n) * calls as f64;
        CommEntry {
            kind,
            calls,
            bytes: bytes as u64 * calls,
            modeled_secs: t,
            measured_secs: t,
        }
    }

    #[test]
    fn recovers_alpha_beta_from_mixed_traffic() {
        let truth = NetModel { alpha: 7e-6, beta_bw: 40e9 };
        let n = 8;
        let g = GroupReport {
            name: "dp".to_string(),
            ranks: n,
            entries: vec![
                entry(&truth, CollectiveKind::AllReduce, 1 << 26, 20, n),
                entry(&truth, CollectiveKind::ReduceScatter, 1 << 14, 20, n),
                entry(&truth, CollectiveKind::Barrier, 0, 5, n),
            ],
        };
        let fit = calibrate(&report_from(vec![g])).unwrap();
        assert!(
            (fit.alpha - truth.alpha).abs() < 1e-9,
            "alpha {} vs {}",
            fit.alpha,
            truth.alpha
        );
        assert!(
            (fit.beta_bw - truth.beta_bw).abs() < 1e-3 * truth.beta_bw,
            "beta {} vs {}",
            fit.beta_bw,
            truth.beta_bw
        );
    }

    #[test]
    fn tp_group_is_excluded_from_the_fit() {
        let truth = NetModel { alpha: 10e-6, beta_bw: 25e9 };
        let wrong = NetModel { alpha: 1e-6, beta_bw: 300e9 };
        let dp = GroupReport {
            name: "dp".to_string(),
            ranks: 4,
            entries: vec![
                entry(&truth, CollectiveKind::AllReduce, 1 << 26, 10, 4),
                entry(&truth, CollectiveKind::Barrier, 0, 10, 4),
            ],
        };
        let tp = GroupReport {
            name: "tp".to_string(),
            ranks: 2,
            entries: vec![entry(
                &wrong,
                CollectiveKind::Gather,
                1 << 26,
                10,
                2,
            )],
        };
        let fit = calibrate(&report_from(vec![dp, tp])).unwrap();
        assert!((fit.alpha - truth.alpha).abs() < 1e-9);
        assert!((fit.beta_bw - truth.beta_bw).abs() < 1e-3 * truth.beta_bw);
    }

    #[test]
    fn single_size_falls_back_to_bandwidth_only() {
        // One kind at one size is rank-deficient: the fit attributes
        // everything to bandwidth, which still reproduces the observed
        // time at that size.
        let truth = NetModel { alpha: 10e-6, beta_bw: 25e9 };
        let n = 8;
        let g = GroupReport {
            name: "dp".to_string(),
            ranks: n,
            entries: vec![entry(
                &truth,
                CollectiveKind::AllReduce,
                1 << 26,
                10,
                n,
            )],
        };
        let fit = calibrate(&report_from(vec![g])).unwrap();
        assert_eq!(fit.alpha, 0.0);
        let want = truth.collective_time(CollectiveKind::AllReduce, 1 << 26, n);
        let got = fit.collective_time(CollectiveKind::AllReduce, 1 << 26, n);
        assert!((got - want).abs() < 1e-6 * want, "{got} vs {want}");
    }

    #[test]
    fn barrier_only_traffic_fits_latency_only() {
        let truth = NetModel { alpha: 5e-6, beta_bw: 25e9 };
        let g = GroupReport {
            name: "dp".to_string(),
            ranks: 8,
            entries: vec![entry(&truth, CollectiveKind::Barrier, 0, 100, 8)],
        };
        let fit = calibrate(&report_from(vec![g])).unwrap();
        assert!((fit.alpha - truth.alpha).abs() < 1e-12);
        assert!(fit.beta_bw.is_infinite());
    }

    #[test]
    fn empty_report_errors() {
        let err = calibrate(&report_from(Vec::new())).unwrap_err();
        assert!(err.to_string().contains("no DP-group collective calls"));
    }
}
