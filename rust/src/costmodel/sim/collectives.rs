//! Collective → op-program lowering: the same ring/grouped algorithms
//! the α–β closed form assumes, expressed as per-rank `Send`/`Recv`
//! chains for the event engine.
//!
//! # Agreement with the closed form
//!
//! On **uniform, contention-free links** the ring collectives are
//! exactly the closed form (`tests/sim_equivalence.rs` pins this):
//! every ring round is `α + slice/β` because all three FIFO resources
//! are free when each round's send executes, and all-reduce /
//! reduce-scatter / all-gather / all-to-all / barrier run exactly the
//! closed form's round count. Two kinds deliberately differ:
//!
//! - **Gather/Scatter** are root-rooted: the bandwidth term matches
//!   (the root's NIC serializes `(n-1)` slices), but the sim pays the
//!   link latency once where the closed form charges `(n-1)·α` — the
//!   sim is the optimistic (pipelined) reading of the same algorithm.
//! - **Broadcast** is a chunked ring pipeline (the simpy HPL-AI
//!   lineage) rather than the closed form's `⌈log₂ n⌉` tree: bandwidth
//!   `≈ s/β` once chunks fill the pipe, latency `(n-1)·α`.
//!
//! Neither kind appears in the gradient-sync path, so the equivalence
//! suite pins only the grad-sync kinds exactly and brackets these two.

use super::engine::Op;
use crate::comm::stats::CollectiveKind;

/// Append `rounds` ring rounds over `group` (rank ids; `ops` is indexed
/// by rank id), each moving `slice` bytes one hop clockwise.
fn ring_rounds(
    ops: &mut [Vec<Op>],
    group: &[usize],
    slice: f64,
    rounds: usize,
) {
    let n = group.len();
    for _ in 0..rounds {
        for (i, &r) in group.iter().enumerate() {
            let next = group[(i + 1) % n];
            let prev = group[(i + n - 1) % n];
            ops[r].push(Op::Send { to: next, bytes: slice });
            ops[r].push(Op::Recv { from: prev });
        }
    }
}

/// Append one collective of `kind` over `group`, moving `bytes` of
/// logical payload. `chunk_bytes` sets the broadcast pipeline chunk.
/// `group[0]` is the root for rooted kinds.
pub fn collective(
    ops: &mut [Vec<Op>],
    group: &[usize],
    kind: CollectiveKind,
    bytes: f64,
    chunk_bytes: f64,
) {
    let n = group.len();
    if n <= 1 {
        return;
    }
    let slice = bytes / n as f64;
    match kind {
        CollectiveKind::Barrier => ring_rounds(ops, group, 0.0, n - 1),
        CollectiveKind::AllReduce => {
            ring_rounds(ops, group, slice, 2 * (n - 1))
        }
        CollectiveKind::AllGather | CollectiveKind::ReduceScatter => {
            ring_rounds(ops, group, slice, n - 1)
        }
        CollectiveKind::AllToAll => {
            // Ring-offset schedule: in round `off` rank `i` exchanges
            // with `i±off` — every round is a perfect matching, so the
            // uniform-link time is the closed form's (n-1)·(α+slice/β).
            for off in 1..n {
                for (i, &r) in group.iter().enumerate() {
                    let to = group[(i + off) % n];
                    let from = group[(i + n - off) % n];
                    ops[r].push(Op::Send { to, bytes: slice });
                    ops[r].push(Op::Recv { from });
                }
            }
        }
        CollectiveKind::Gather => {
            let root = group[0];
            for &r in &group[1..] {
                ops[r].push(Op::Send { to: root, bytes: slice });
                ops[root].push(Op::Recv { from: r });
            }
        }
        CollectiveKind::Scatter => {
            let root = group[0];
            for &r in &group[1..] {
                ops[root].push(Op::Send { to: r, bytes: slice });
                ops[r].push(Op::Recv { from: root });
            }
        }
        CollectiveKind::Broadcast => {
            // Chunked chain pipeline: the root streams K chunks down
            // the ring; every hop forwards chunk k while k+1 is still
            // in flight.
            let k = if chunk_bytes > 0.0 {
                (bytes / chunk_bytes).ceil().max(1.0) as usize
            } else {
                1
            };
            let cb = bytes / k as f64;
            for (i, &r) in group.iter().enumerate() {
                for _ in 0..k {
                    if i > 0 {
                        ops[r].push(Op::Recv { from: group[i - 1] });
                    }
                    if i + 1 < n {
                        ops[r].push(Op::Send { to: group[i + 1], bytes: cb });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{ns_to_secs, run, Proc, SimNet};
    use super::*;
    use crate::costmodel::netmodel::NetModel;

    fn time_of(kind: CollectiveKind, bytes: usize, n: usize) -> f64 {
        let net = NetModel::ib_hdr();
        let mut ops: Vec<Vec<Op>> = vec![Vec::new(); n];
        let group: Vec<usize> = (0..n).collect();
        collective(&mut ops, &group, kind, bytes as f64, (1 << 20) as f64);
        let procs: Vec<Proc> = ops
            .into_iter()
            .enumerate()
            .map(|(r, ops)| Proc { rank: r, ops })
            .collect();
        ns_to_secs(run(&SimNet::uniform(net), &procs).makespan)
    }

    #[test]
    fn ring_kinds_match_closed_form() {
        // The grad-sync kinds (plus barrier and all-to-all) agree with
        // the α–β formula to ns rounding on uniform links.
        let net = NetModel::ib_hdr();
        for kind in [
            CollectiveKind::Barrier,
            CollectiveKind::AllReduce,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllGather,
            CollectiveKind::AllToAll,
        ] {
            for n in [2, 3, 8] {
                for bytes in [4usize << 10, 1 << 24] {
                    let sim = time_of(kind, bytes, n);
                    let cf = net.collective_time(kind, bytes, n);
                    // ≤ 1.5 ns rounding per round, a handful of rounds.
                    assert!(
                        (sim - cf).abs() <= 1e-3 * cf.max(1e-9),
                        "{kind:?} n={n} b={bytes}: sim {sim} vs cf {cf}"
                    );
                }
            }
        }
    }

    #[test]
    fn gather_pays_root_ingress_serialization() {
        // The root's NIC takes the (n-1) slices one at a time: the
        // bandwidth term matches the closed form; the α term is 1·α in
        // the sim vs (n-1)·α closed-form, so sim ≤ closed form, and
        // both exceed the pure bandwidth bound.
        let net = NetModel::ib_hdr();
        let (bytes, n) = (1usize << 24, 8);
        let sim = time_of(CollectiveKind::Gather, bytes, n);
        let cf = net.collective_time(CollectiveKind::Gather, bytes, n);
        let bw_term =
            bytes as f64 * (n as f64 - 1.0) / n as f64 / net.beta_bw;
        assert!(sim <= cf + 1e-9, "sim {sim} vs cf {cf}");
        assert!(sim > bw_term, "sim {sim} vs bw bound {bw_term}");
        assert!((sim - (bw_term + net.alpha)).abs() < 1e-3 * sim);
    }

    #[test]
    fn broadcast_chunks_pipeline_the_chain() {
        // More chunks → shorter chain makespan (the pipeline fills),
        // bounded below by the serialization of the full payload.
        let net = NetModel::ib_hdr();
        let (bytes, n) = (1usize << 24, 4);
        let t_of = |chunk: f64| {
            let mut ops: Vec<Vec<Op>> = vec![Vec::new(); n];
            let group: Vec<usize> = (0..n).collect();
            collective(
                &mut ops,
                &group,
                CollectiveKind::Broadcast,
                bytes as f64,
                chunk,
            );
            let procs: Vec<Proc> = ops
                .into_iter()
                .enumerate()
                .map(|(r, ops)| Proc { rank: r, ops })
                .collect();
            ns_to_secs(run(&SimNet::uniform(net), &procs).makespan)
        };
        let one = t_of(bytes as f64); // single chunk: store-and-forward
        let many = t_of((bytes / 16) as f64);
        assert!(many < one, "chunked {many} !< monolithic {one}");
        assert!(many > bytes as f64 / net.beta_bw);
    }
}
