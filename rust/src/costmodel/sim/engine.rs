//! Deterministic discrete-event engine: ranks as op-list processes,
//! links as FIFO α–β resources, a binary-heap event queue over an
//! integer-nanosecond virtual clock.
//!
//! # Determinism contract
//!
//! Same inputs → bit-identical [`SimResult`], on every host, every run:
//!
//! - the virtual clock is **integer nanoseconds** — floats appear only
//!   when a payload size is converted to a serialization duration, and
//!   are immediately `ceil`ed to whole ns, so no float accumulation can
//!   reorder events;
//! - heap ties are broken by a monotone sequence number assigned at
//!   push, making event order a total order independent of allocator or
//!   hash state (no `HashMap` anywhere — `BTreeMap` only);
//! - there is no wall-clock read and no RNG.
//!
//! # Transfer model (cut-through)
//!
//! One `Send` of `b` bytes from `src` to `dst` claims three FIFO
//! resources in order, each for the serialization time `b/β`:
//!
//! 1. the sender's **egress NIC** (serializes that rank's outgoing
//!    transfers),
//! 2. the directed `(src, dst)` **wire**,
//! 3. the receiver's **ingress NIC** (serializes fan-in — the resource
//!    the closed form cannot see).
//!
//! The first byte then needs `α` of link latency, so the matching
//! `Recv` completes at `ingress_start + b/β + α`; the sender resumes
//! once the payload is fully on the wire. With a single transfer in
//! flight this is exactly the α–β closed form (`α + b/β`), which is why
//! contention-free ring collectives agree with
//! [`NetModel::collective_time`] to ns rounding
//! (`tests/sim_equivalence.rs`); under fan-in the ingress NIC
//! serializes rounds the closed form counts once — the contention this
//! simulator exists to expose.
//!
//! [`NetModel::collective_time`]: crate::costmodel::netmodel::NetModel::collective_time

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use crate::costmodel::netmodel::NetModel;

/// Virtual time in integer nanoseconds.
pub type Ns = u64;

/// Seconds → virtual ns (saturating at 0 for negative inputs).
pub fn secs_to_ns(s: f64) -> Ns {
    if s <= 0.0 {
        0
    } else {
        (s * 1e9).round() as Ns
    }
}

/// Virtual ns → seconds.
pub fn ns_to_secs(ns: Ns) -> f64 {
    ns as f64 / 1e9
}

/// α–β parameters of one directed link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// First-byte latency (α), ns.
    pub latency_ns: Ns,
    /// Serialization bandwidth (β), bytes/second.
    pub bytes_per_sec: f64,
}

impl LinkParams {
    pub fn from_net(net: NetModel) -> LinkParams {
        LinkParams {
            latency_ns: secs_to_ns(net.alpha),
            bytes_per_sec: net.beta_bw,
        }
    }

    /// Time to put `bytes` on the wire, rounded **up** to whole ns —
    /// the only float→clock conversion in the engine (≤ 1 ns error per
    /// transfer, which bounds the sim-vs-closed-form divergence stated
    /// in `tests/sim_equivalence.rs`).
    pub fn serialize_ns(&self, bytes: f64) -> Ns {
        if bytes <= 0.0
            || !self.bytes_per_sec.is_finite()
            || self.bytes_per_sec <= 0.0
        {
            return 0;
        }
        (bytes / self.bytes_per_sec * 1e9).ceil() as Ns
    }
}

/// The cluster fabric: a default link plus per-pair overrides (for
/// heterogeneous fabrics — e.g. a reduced world whose DP ranks run on
/// IB while the TP ranks run on NVLink) and per-source extra latency
/// (the `--sim-slow-link` fail-slow vocabulary, `robust::SlowLink`).
#[derive(Debug, Clone)]
pub struct SimNet {
    pub default: LinkParams,
    /// Directed per-pair overrides.
    pub overrides: BTreeMap<(usize, usize), LinkParams>,
    /// Extra latency added to every transfer *sent by* this rank.
    pub extra_send_latency: BTreeMap<usize, Ns>,
}

impl SimNet {
    pub fn uniform(net: NetModel) -> SimNet {
        SimNet {
            default: LinkParams::from_net(net),
            overrides: BTreeMap::new(),
            extra_send_latency: BTreeMap::new(),
        }
    }

    fn params(&self, src: usize, dst: usize) -> LinkParams {
        *self.overrides.get(&(src, dst)).unwrap_or(&self.default)
    }

    fn latency_ns(&self, src: usize, dst: usize) -> Ns {
        self.params(src, dst).latency_ns
            + self.extra_send_latency.get(&src).copied().unwrap_or(0)
    }
}

/// One instruction of a rank process.
#[derive(Debug, Clone, Copy)]
pub enum Op {
    /// Occupy the local compute resource for this many virtual ns.
    Compute(Ns),
    /// Inject `bytes` toward rank `to`; the process resumes once the
    /// payload is fully on the wire. Bytes are `f64` so ring slices
    /// (`payload / n`) carry no integer-division drift against the
    /// closed form.
    Send { to: usize, bytes: f64 },
    /// Block until the next message from rank `from` (FIFO per
    /// directed pair).
    Recv { from: usize },
    /// Block until signal `sig` has fired (no-op if already fired).
    Wait { sig: usize },
    /// Fire signal `sig` at the current local time (idempotent —
    /// the earliest firing wins; waiters wake at the fire time).
    Fire { sig: usize },
}

/// A rank process: `rank` names the NIC/link endpoints its transfers
/// use. Several processes may share a rank only if they never race on
/// the same peer's message FIFO.
#[derive(Debug, Clone)]
pub struct Proc {
    pub rank: usize,
    pub ops: Vec<Op>,
}

/// Everything a run produces — `PartialEq` so bit-reproducibility is a
/// one-line assertion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimResult {
    /// Last event time (virtual ns): the makespan.
    pub makespan: Ns,
    /// Per-process finish times, in process order.
    pub finish: Vec<Ns>,
    /// Heap events processed (scale telemetry).
    pub events: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// Re-enter process `p` at its current program counter.
    Resume(usize),
    /// A message from rank `src` lands at rank `dst`.
    Arrive { src: usize, dst: usize },
}

struct Queue {
    heap: BinaryHeap<Reverse<(Ns, u64, Ev)>>,
    seq: u64,
    events: u64,
}

impl Queue {
    fn push(&mut self, t: Ns, ev: Ev) {
        self.heap.push(Reverse((t, self.seq, ev)));
        self.seq += 1;
    }

    fn pop(&mut self) -> Option<(Ns, Ev)> {
        self.heap.pop().map(|Reverse((t, _, ev))| {
            self.events += 1;
            (t, ev)
        })
    }
}

struct Engine<'a> {
    net: &'a SimNet,
    procs: &'a [Proc],
    q: Queue,
    pc: Vec<usize>,
    finish: Vec<Ns>,
    done: Vec<bool>,
    /// Next free time of each rank's egress NIC.
    egress_free: BTreeMap<usize, Ns>,
    /// Next free time of each rank's ingress NIC.
    ingress_free: BTreeMap<usize, Ns>,
    /// Next free time of each directed wire.
    wire_free: BTreeMap<(usize, usize), Ns>,
    /// Arrived-but-unconsumed messages per directed pair (arrival ns).
    mailbox: BTreeMap<(usize, usize), VecDeque<Ns>>,
    /// Processes blocked on `Recv` per directed pair, FIFO.
    recv_wait: BTreeMap<(usize, usize), VecDeque<usize>>,
    /// Fired signals → fire time.
    sig_fired: BTreeMap<usize, Ns>,
    /// Processes blocked on `Wait`.
    sig_wait: BTreeMap<usize, Vec<usize>>,
    makespan: Ns,
}

impl Engine<'_> {
    /// Execute process `p` from its program counter at virtual time
    /// `now` until it blocks, yields to the heap, or finishes.
    fn advance(&mut self, p: usize, now: Ns) {
        loop {
            let Some(&op) = self.procs[p].ops.get(self.pc[p]) else {
                self.done[p] = true;
                self.finish[p] = now;
                self.makespan = self.makespan.max(now);
                return;
            };
            match op {
                Op::Compute(d) => {
                    self.pc[p] += 1;
                    self.q.push(now + d, Ev::Resume(p));
                    return;
                }
                Op::Send { to, bytes } => {
                    let src = self.procs[p].rank;
                    let pars = self.net.params(src, to);
                    let ser = pars.serialize_ns(bytes);
                    let lat = self.net.latency_ns(src, to);
                    // FIFO acquisition in event order (the heap pops in
                    // time order, so sends are globally serialized the
                    // way a real NIC queue would see them).
                    let eg = self.egress_free.get(&src).copied().unwrap_or(0);
                    let t_eg = now.max(eg);
                    self.egress_free.insert(src, t_eg + ser);
                    let wf =
                        self.wire_free.get(&(src, to)).copied().unwrap_or(0);
                    let t_wire = t_eg.max(wf);
                    self.wire_free.insert((src, to), t_wire + ser);
                    let inf = self.ingress_free.get(&to).copied().unwrap_or(0);
                    let t_in = t_wire.max(inf);
                    self.ingress_free.insert(to, t_in + ser);
                    self.q.push(t_in + ser + lat, Ev::Arrive { src, dst: to });
                    self.pc[p] += 1;
                    let injected = t_wire + ser;
                    if injected > now {
                        self.q.push(injected, Ev::Resume(p));
                        return;
                    }
                    // Zero-cost injection (0 bytes, idle wire): continue
                    // inline at the same virtual time.
                }
                Op::Recv { from } => {
                    let dst = self.procs[p].rank;
                    let key = (from, dst);
                    let hit = self
                        .mailbox
                        .get_mut(&key)
                        .and_then(|q| q.pop_front());
                    match hit {
                        // The message already arrived (arrival ≤ now,
                        // since Arrive events are processed in time
                        // order): consume and continue at `now`.
                        Some(_arrived) => self.pc[p] += 1,
                        None => {
                            self.recv_wait
                                .entry(key)
                                .or_default()
                                .push_back(p);
                            return;
                        }
                    }
                }
                Op::Wait { sig } => {
                    if self.sig_fired.contains_key(&sig) {
                        self.pc[p] += 1;
                    } else {
                        self.sig_wait.entry(sig).or_default().push(p);
                        return;
                    }
                }
                Op::Fire { sig } => {
                    self.sig_fired.entry(sig).or_insert(now);
                    if let Some(ws) = self.sig_wait.remove(&sig) {
                        for w in ws {
                            self.pc[w] += 1;
                            self.q.push(now, Ev::Resume(w));
                        }
                    }
                    self.pc[p] += 1;
                }
            }
        }
    }

    fn handle_arrive(&mut self, src: usize, dst: usize, t: Ns) {
        let key = (src, dst);
        let waiter = self
            .recv_wait
            .get_mut(&key)
            .and_then(|q| q.pop_front());
        match waiter {
            Some(w) => {
                self.pc[w] += 1;
                self.q.push(t, Ev::Resume(w));
            }
            None => self.mailbox.entry(key).or_default().push_back(t),
        }
    }
}

/// Run the processes to completion and return the virtual makespan.
///
/// Panics on deadlock (a process still blocked on `Recv`/`Wait` when
/// the event queue drains) — a program-construction bug must fail
/// loudly rather than return a bogus makespan.
pub fn run(net: &SimNet, procs: &[Proc]) -> SimResult {
    let np = procs.len();
    let mut eng = Engine {
        net,
        procs,
        q: Queue { heap: BinaryHeap::new(), seq: 0, events: 0 },
        pc: vec![0; np],
        finish: vec![0; np],
        done: vec![false; np],
        egress_free: BTreeMap::new(),
        ingress_free: BTreeMap::new(),
        wire_free: BTreeMap::new(),
        mailbox: BTreeMap::new(),
        recv_wait: BTreeMap::new(),
        sig_fired: BTreeMap::new(),
        sig_wait: BTreeMap::new(),
        makespan: 0,
    };
    for p in 0..np {
        eng.q.push(0, Ev::Resume(p));
    }
    while let Some((t, ev)) = eng.q.pop() {
        eng.makespan = eng.makespan.max(t);
        match ev {
            Ev::Resume(p) => {
                if !eng.done[p] {
                    eng.advance(p, t);
                }
            }
            Ev::Arrive { src, dst } => eng.handle_arrive(src, dst, t),
        }
    }
    assert!(
        eng.done.iter().all(|&d| d),
        "sim deadlock: {} process(es) still blocked when the event \
         queue drained",
        eng.done.iter().filter(|&&d| !d).count()
    );
    SimResult {
        makespan: eng.makespan,
        finish: eng.finish,
        events: eng.q.events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(alpha: f64, bw: f64) -> SimNet {
        SimNet::uniform(NetModel { alpha, beta_bw: bw })
    }

    #[test]
    fn single_transfer_is_alpha_plus_beta() {
        // One 1 MiB send over a 1 GB/s, 10 µs link: receiver finishes at
        // exactly α + b/β; sender resumes at b/β.
        let n = net(10e-6, 1e9);
        let b = (1u64 << 20) as f64;
        let procs = [
            Proc { rank: 0, ops: vec![Op::Send { to: 1, bytes: b }] },
            Proc { rank: 1, ops: vec![Op::Recv { from: 0 }] },
        ];
        let r = run(&n, &procs);
        let ser = (b / 1e9 * 1e9).ceil() as Ns;
        assert_eq!(r.finish[0], ser);
        assert_eq!(r.finish[1], ser + 10_000);
        assert_eq!(r.makespan, ser + 10_000);
    }

    #[test]
    fn egress_nic_serializes_back_to_back_sends() {
        // Two sends from rank 0 to different peers share one egress
        // NIC: program order already serializes them (the sender only
        // resumes after injection), and each receiver sees its own α.
        let n = net(5e-6, 1e9);
        let b = 1e6;
        let procs = [
            Proc {
                rank: 0,
                ops: vec![
                    Op::Send { to: 1, bytes: b },
                    Op::Send { to: 2, bytes: b },
                ],
            },
            Proc { rank: 1, ops: vec![Op::Recv { from: 0 }] },
            Proc { rank: 2, ops: vec![Op::Recv { from: 0 }] },
        ];
        let r = run(&n, &procs);
        let ser = 1_000_000; // 1e6 B / 1e9 B/s = 1 ms
        assert_eq!(r.finish[1], ser + 5_000);
        assert_eq!(r.finish[2], 2 * ser + 5_000);
    }

    #[test]
    fn ingress_nic_serializes_fan_in() {
        // Two senders into one receiver: the wire legs run in parallel
        // but the ingress NIC takes them one at a time.
        let n = net(0.0, 1e9);
        let b = 1e6;
        let procs = [
            Proc { rank: 0, ops: vec![Op::Send { to: 2, bytes: b }] },
            Proc { rank: 1, ops: vec![Op::Send { to: 2, bytes: b }] },
            Proc {
                rank: 2,
                ops: vec![Op::Recv { from: 0 }, Op::Recv { from: 1 }],
            },
        ];
        let r = run(&n, &procs);
        // First arrival at ser, second serialized behind it at 2·ser.
        assert_eq!(r.finish[2], 2_000_000);
    }

    #[test]
    fn slow_link_latency_is_per_source() {
        let mut n = net(1e-6, 1e9);
        n.extra_send_latency.insert(0, 500_000); // +0.5 ms from rank 0
        let procs = [
            Proc { rank: 0, ops: vec![Op::Send { to: 1, bytes: 0.0 }] },
            Proc { rank: 1, ops: vec![Op::Recv { from: 0 }] },
        ];
        let r = run(&n, &procs);
        assert_eq!(r.finish[1], 1_000 + 500_000);
    }

    #[test]
    fn signals_build_the_slab_pipeline() {
        // Producer computes 4 slabs of 8 units; consumer computes 4
        // slabs of 2 units gated per slab: finish = 32 + 2 (the
        // overlap_pipeline closed form max(C,K) + min/S with C=32 K=8
        // S=4).
        let n = net(0.0, f64::INFINITY);
        let mut prod = Vec::new();
        let mut cons = Vec::new();
        for s in 0..4 {
            prod.push(Op::Compute(8));
            prod.push(Op::Fire { sig: s });
            cons.push(Op::Wait { sig: s });
            cons.push(Op::Compute(2));
        }
        let r = run(
            &n,
            &[Proc { rank: 0, ops: prod }, Proc { rank: 1, ops: cons }],
        );
        assert_eq!(r.makespan, 34);
    }

    #[test]
    fn runs_are_bit_reproducible() {
        // Same program twice → identical SimResult, including the event
        // count (the determinism contract in the module docs).
        let n = net(2e-6, 5e8);
        let mk = || {
            let mut ops: Vec<Vec<Op>> = vec![Vec::new(); 4];
            for r in 0..4usize {
                for k in 0..6 {
                    let to = (r + 1) % 4;
                    let from = (r + 3) % 4;
                    ops[r].push(Op::Send { to, bytes: 1e5 * (k + 1) as f64 });
                    ops[r].push(Op::Recv { from });
                    ops[r].push(Op::Compute(777));
                }
            }
            ops.into_iter()
                .enumerate()
                .map(|(r, ops)| Proc { rank: r, ops })
                .collect::<Vec<_>>()
        };
        let a = run(&n, &mk());
        let b = run(&n, &mk());
        assert_eq!(a, b);
        assert!(a.events > 0);
    }

    #[test]
    #[should_panic(expected = "sim deadlock")]
    fn deadlock_panics_loudly() {
        let n = net(0.0, 1e9);
        run(&n, &[Proc { rank: 0, ops: vec![Op::Recv { from: 1 }] }]);
    }
}
