//! `StepSchedule`: replay one MuonBP optimizer step — DP gradient sync,
//! TP gather/NS/scatter on full steps, blockwise NS on block steps —
//! as an event program, derived from the same `ShardSpec` /
//! `StateSharding` / `Topology` / period configuration the real
//! coordinator builds from.
//!
//! # Reduced world
//!
//! The simulated world is **one DP group** (ranks `0..dp`, on the DP
//! fabric) plus **one TP group** (ranks `dp..dp+tp`, on the TP fabric
//! via per-pair link overrides). Under both topologies the other
//! replica groups are symmetric and run on disjoint links, so one
//! representative of each is exact — and it keeps a
//! tp=8 × dp=1024 cell at ~1k processes instead of 8k.
//!
//! - The DP sync payload is the **fused** sum of every hidden matrix
//!   (the coordinator syncs them back-to-back on the same
//!   communicator), divided by `tp` under the grouped topology —
//!   exactly the coordinator's shard-sized `block_bytes(g)` charging.
//! - The DAG executor's slab pipeline appears as `n_slabs` signals: the
//!   rank-0 sync lane fires signal `s` when slab `s`'s rounds complete,
//!   and the compute process consumes one block-NS segment per signal.
//!   With uniform slabs this reproduces
//!   [`overlap_pipeline`](crate::costmodel::netmodel::overlap_pipeline)
//!   exactly — the closed form is the degenerate special case.
//! - Compute durations mirror `costmodel/throughput`: full-step NS is
//!   `ns_flops / (opt_flops · dp)` per matrix on the TP leader; block
//!   steps run every block's NS at `Σ block_flops / (opt_flops · dp·tp)`.
//!
//! # Fault injection
//!
//! Shares `robust`'s vocabulary: a [`SlowLink`] adds `delay_ms` of
//! latency to every transfer the target DP rank *sends* (fail-slow, not
//! fail-stop); a [`Straggler`] delays the rank's entry into the sync by
//! `delay_ms`. Attempts are 1-based and map onto the representative
//! step of their period slot: attempt `a` lands on the full step iff
//! `a % period == 1 % period`, else on the block step.

use std::collections::BTreeMap;

use super::collectives;
use super::engine::{
    ns_to_secs, run, secs_to_ns, LinkParams, Ns, Op, Proc, SimNet,
};
use crate::comm::stats::CollectiveKind;
use crate::costmodel::netmodel::NetModel;
use crate::linalg::newton_schulz::ns_flops;
use crate::mesh::{Layout, StateSharding, Topology};
use crate::robust::{SlowLink, Straggler};
use crate::shard::ShardSpec;

/// The coordinator-equivalent step configuration.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleCfg {
    pub dp: usize,
    pub tp: usize,
    pub layout: Layout,
    pub sharding: StateSharding,
    pub topology: Topology,
    /// Orthogonalization period P (1 = Muon, every step full).
    pub period: usize,
    /// DP-sync slab granularity (the DAG executor's row slabs).
    pub n_slabs: usize,
    /// `false` degenerates to the serial barrier schedule (compute
    /// starts only after the last slab lands).
    pub overlap: bool,
    /// Broadcast pipeline chunk, bytes.
    pub chunk_bytes: usize,
}

/// Per-rank compute rates, from the HW preset (`peak·opt_eff`).
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// FLOP/s available to optimizer GEMMs per rank.
    pub opt_flops_per_sec: f64,
    /// Newton–Schulz iteration count.
    pub ns_steps: usize,
}

/// The two fabrics of the reduced world.
#[derive(Debug, Clone, Copy)]
pub struct FabricLinks {
    pub dp: LinkParams,
    pub tp: LinkParams,
}

impl FabricLinks {
    pub fn from_nets(dp_net: NetModel, tp_net: NetModel) -> FabricLinks {
        FabricLinks {
            dp: LinkParams::from_net(dp_net),
            tp: LinkParams::from_net(tp_net),
        }
    }
}

/// Fail-slow injection for a simulated run ([`SlowLink`] /
/// [`Straggler`] are `robust`'s CLI-parsed vocabulary).
#[derive(Debug, Clone, Default)]
pub struct SimFaults {
    pub slow_links: Vec<SlowLink>,
    pub stragglers: Vec<Straggler>,
}

/// Which representative step to replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    /// Every-P-th step: DP sync, then TP gather → full NS → scatter.
    Full,
    /// The other P−1 steps: DP sync overlapped with blockwise NS.
    Block,
}

/// Wall-clock projections for one step configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepTimes {
    pub full_secs: f64,
    pub block_secs: f64,
    /// Period-weighted optimizer step time:
    /// `(full + (P−1)·block) / P`.
    pub avg_secs: f64,
}

/// A priced, replayable optimizer step.
#[derive(Debug, Clone)]
pub struct StepSchedule {
    pub cfg: ScheduleCfg,
    /// Fused DP-sync payload in bytes (all hidden matrices; divided by
    /// `tp` under the grouped topology).
    pub sync_bytes: f64,
    /// Full-matrix bytes per matrix (TP gather/scatter payloads).
    pub matrix_bytes: Vec<f64>,
    /// Full-step NS duration per matrix on the leader, virtual ns.
    pub full_ns: Vec<Ns>,
    /// Whole-model block-step NS duration per compute lane, virtual ns.
    pub block_ns_total: Ns,
}

fn sync_kinds(sharding: StateSharding) -> &'static [CollectiveKind] {
    match sharding {
        StateSharding::Replicated => &[CollectiveKind::AllReduce],
        StateSharding::Zero1 => {
            &[CollectiveKind::ReduceScatter, CollectiveKind::AllGather]
        }
        StateSharding::Zero2 => &[CollectiveKind::ReduceScatter],
    }
}

impl StepSchedule {
    /// Derive the schedule from matrix shapes (e.g.
    /// `ModelDims::all_matrix_shapes`) the way `DistMuonBuilder::build`
    /// derives its specs: one `ShardSpec` per matrix under the given
    /// layout/tp, sync payload summed over all matrices.
    pub fn new(
        cfg: ScheduleCfg,
        shapes: &[(usize, usize)],
        cm: &ComputeModel,
    ) -> anyhow::Result<StepSchedule> {
        anyhow::ensure!(
            cfg.dp >= 1 && cfg.tp >= 1,
            "sim: zero ranks (dp={}, tp={})",
            cfg.dp,
            cfg.tp
        );
        anyhow::ensure!(cfg.period >= 1, "sim: period must be >= 1");
        anyhow::ensure!(cfg.n_slabs >= 1, "sim: n_slabs must be >= 1");
        anyhow::ensure!(!shapes.is_empty(), "sim: no matrix shapes");
        anyhow::ensure!(
            cm.opt_flops_per_sec > 0.0,
            "sim: opt_flops_per_sec must be positive"
        );
        let opt = cm.opt_flops_per_sec;
        let mut total_bytes = 0.0;
        let mut matrix_bytes = Vec::with_capacity(shapes.len());
        let mut full_ns = Vec::with_capacity(shapes.len());
        let mut block_flops = 0.0;
        for &(m, n) in shapes {
            let spec = ShardSpec::new(cfg.layout, cfg.tp, m, n);
            let bytes = (m * n * 4) as f64;
            total_bytes += bytes;
            matrix_bytes.push(bytes);
            full_ns.push(secs_to_ns(
                ns_flops(m, n, cm.ns_steps) / (opt * cfg.dp as f64),
            ));
            for b in 0..spec.num_blocks() {
                let (bm, bn) = spec.block_shape(b);
                block_flops += ns_flops(bm.max(1), bn.max(1), cm.ns_steps);
            }
        }
        let div = if cfg.topology == Topology::GroupedPerShard {
            cfg.tp.max(1) as f64
        } else {
            1.0
        };
        Ok(StepSchedule {
            cfg,
            sync_bytes: total_bytes / div,
            matrix_bytes,
            full_ns,
            block_ns_total: secs_to_ns(
                block_flops / (opt * (cfg.dp * cfg.tp) as f64),
            ),
        })
    }

    /// Build the reduced-world fabric: DP links as the default,
    /// per-pair overrides for the TP group, fail-slow latency from
    /// `faults`.
    fn fabric(&self, links: FabricLinks, faults: &SimFaults) -> SimNet {
        let (dp, tp) = (self.cfg.dp, self.cfg.tp);
        let mut overrides = BTreeMap::new();
        for i in 0..tp {
            for j in 0..tp {
                if i != j {
                    overrides.insert((dp + i, dp + j), links.tp);
                }
            }
        }
        let mut extra_send_latency: BTreeMap<usize, Ns> = BTreeMap::new();
        for sl in &faults.slow_links {
            if sl.rank < dp {
                *extra_send_latency.entry(sl.rank).or_insert(0) +=
                    sl.delay_ms * 1_000_000;
            }
        }
        SimNet { default: links.dp, overrides, extra_send_latency }
    }

    /// Replay one step of `kind`; returns the virtual-ns makespan.
    pub fn step_time_ns(
        &self,
        kind: StepKind,
        links: FabricLinks,
        faults: &SimFaults,
    ) -> Ns {
        let (dp, tp) = (self.cfg.dp, self.cfg.tp);
        let n_slabs = self.cfg.n_slabs;
        let net = self.fabric(links, faults);
        let mut ops: Vec<Vec<Op>> = vec![Vec::new(); dp + tp];
        // Stragglers delay the rank's entry into the sync.
        for st in &faults.stragglers {
            if st.rank < dp {
                ops[st.rank].push(Op::Compute(st.delay_ms * 1_000_000));
            }
        }
        // DP sync, slab-pipelined: the rank-0 lane fires signal s when
        // its rounds for slab s are done (in a contention-free ring all
        // lanes finish a slab simultaneously; under faults the ring's
        // round coupling propagates the slowdown to lane 0 within one
        // ring traversal).
        let group: Vec<usize> = (0..dp).collect();
        let slab_bytes = self.sync_bytes / n_slabs as f64;
        let chunk = self.cfg.chunk_bytes as f64;
        for s in 0..n_slabs {
            if dp > 1 {
                for &k in sync_kinds(self.cfg.sharding) {
                    collectives::collective(
                        &mut ops, &group, k, slab_bytes, chunk,
                    );
                }
            }
            ops[0].push(Op::Fire { sig: s });
        }
        match kind {
            StepKind::Full => {
                // TP phase: gather each matrix to the leader, full NS,
                // scatter the update — serial per matrix, mirroring the
                // coordinator (full-step TP comm is not yet
                // slab-overlapped; see ROADMAP PR-8 notes).
                let leader = dp;
                for r in 0..tp {
                    ops[dp + r].push(Op::Wait { sig: n_slabs - 1 });
                }
                for (i, &mb) in self.matrix_bytes.iter().enumerate() {
                    let slice = mb / tp as f64;
                    for p in 1..tp {
                        ops[dp + p].push(Op::Send { to: leader, bytes: slice });
                        ops[leader].push(Op::Recv { from: dp + p });
                    }
                    ops[leader].push(Op::Compute(self.full_ns[i]));
                    for p in 1..tp {
                        ops[leader].push(Op::Send { to: dp + p, bytes: slice });
                        ops[dp + p].push(Op::Recv { from: leader });
                    }
                }
            }
            StepKind::Block => {
                // Blockwise NS on the TP ranks (identical per rank —
                // one representative process), slab-gated when the DAG
                // overlap is on.
                let c = &mut ops[dp];
                if self.cfg.overlap && n_slabs > 1 {
                    let per = self.block_ns_total / n_slabs as u64;
                    let last =
                        self.block_ns_total - per * (n_slabs as u64 - 1);
                    for s in 0..n_slabs {
                        c.push(Op::Wait { sig: s });
                        c.push(Op::Compute(if s + 1 == n_slabs {
                            last
                        } else {
                            per
                        }));
                    }
                } else {
                    c.push(Op::Wait { sig: n_slabs - 1 });
                    c.push(Op::Compute(self.block_ns_total));
                }
            }
        }
        let procs: Vec<Proc> = ops
            .into_iter()
            .enumerate()
            .map(|(r, ops)| Proc { rank: r, ops })
            .collect();
        run(&net, &procs).makespan
    }

    /// Period-weighted step projection. With faults present, the
    /// representative full/block step absorbs every fault whose attempt
    /// maps to it, and the average assumes the fault recurs each period
    /// — the pessimistic steady state (the single-projection CLI mode
    /// prints full/block separately for the one-shot reading).
    pub fn avg_step(
        &self,
        links: FabricLinks,
        faults: &SimFaults,
    ) -> StepTimes {
        let p = self.cfg.period.max(1) as u64;
        let mut on_full = SimFaults::default();
        let mut on_block = SimFaults::default();
        for sl in &faults.slow_links {
            if sl.attempt % p == 1 % p {
                on_full.slow_links.push(*sl);
            } else {
                on_block.slow_links.push(*sl);
            }
        }
        for st in &faults.stragglers {
            if st.attempt % p == 1 % p {
                on_full.stragglers.push(*st);
            } else {
                on_block.stragglers.push(*st);
            }
        }
        let full = self.step_time_ns(StepKind::Full, links, &on_full);
        let block = if p > 1 {
            self.step_time_ns(StepKind::Block, links, &on_block)
        } else {
            0
        };
        StepTimes {
            full_secs: ns_to_secs(full),
            block_secs: ns_to_secs(block),
            avg_secs: (full as f64 + (p - 1) as f64 * block as f64)
                / p as f64
                / 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::netmodel::NetModel;

    fn cfg(dp: usize, tp: usize, period: usize) -> ScheduleCfg {
        ScheduleCfg {
            dp,
            tp,
            layout: Layout::TpColumn,
            sharding: StateSharding::Replicated,
            topology: Topology::FullReplica,
            period,
            n_slabs: 4,
            overlap: true,
            chunk_bytes: 1 << 20,
        }
    }

    fn cm() -> ComputeModel {
        ComputeModel { opt_flops_per_sec: 312e12 * 0.18, ns_steps: 5 }
    }

    fn links() -> FabricLinks {
        FabricLinks::from_nets(NetModel::ib_hdr(), NetModel::a100_nvlink())
    }

    #[test]
    fn rejects_degenerate_configs() {
        let shapes = [(256usize, 256usize)];
        assert!(StepSchedule::new(cfg(0, 1, 1), &shapes, &cm()).is_err());
        assert!(StepSchedule::new(cfg(2, 0, 1), &shapes, &cm()).is_err());
        let mut c = cfg(2, 1, 1);
        c.period = 0;
        assert!(StepSchedule::new(c, &shapes, &cm()).is_err());
        let mut c = cfg(2, 1, 1);
        c.n_slabs = 0;
        assert!(StepSchedule::new(c, &shapes, &cm()).is_err());
        assert!(StepSchedule::new(cfg(2, 1, 1), &[], &cm()).is_err());
    }

    #[test]
    fn longer_periods_shrink_the_average_step() {
        // The MuonBP claim in miniature: the full step pays TP
        // gather/scatter + full NS, block steps don't — so the
        // period-weighted average falls as P grows.
        let shapes = [(2048usize, 2048usize), (2048, 8192)];
        let t1 = StepSchedule::new(cfg(4, 4, 1), &shapes, &cm())
            .unwrap()
            .avg_step(links(), &SimFaults::default());
        let t4 = StepSchedule::new(cfg(4, 4, 4), &shapes, &cm())
            .unwrap()
            .avg_step(links(), &SimFaults::default());
        assert!(
            t4.avg_secs < t1.avg_secs,
            "P=4 {} !< P=1 {}",
            t4.avg_secs,
            t1.avg_secs
        );
        // And the block step is strictly cheaper than the full step.
        assert!(t4.block_secs < t4.full_secs);
    }

    #[test]
    fn grouped_topology_syncs_the_shard_payload() {
        let shapes = [(1024usize, 1024usize)];
        let full = StepSchedule::new(cfg(4, 4, 1), &shapes, &cm()).unwrap();
        let mut gc = cfg(4, 4, 1);
        gc.topology = Topology::GroupedPerShard;
        let grouped = StepSchedule::new(gc, &shapes, &cm()).unwrap();
        assert!(
            (grouped.sync_bytes - full.sync_bytes / 4.0).abs() < 1e-9,
            "{} vs {}/4",
            grouped.sync_bytes,
            full.sync_bytes
        );
    }
}
