//! Discrete-event cluster simulator.
//!
//! A deterministic event-driven model of the training fabric: ranks are
//! processes, links are per-pair latency/bandwidth resources with FIFO
//! contention (egress NIC → directed wire → ingress NIC), collectives
//! are the ring/grouped algorithms of `comm/` lowered to per-rank
//! `Send`/`Recv` chains, and compute segments come from
//! `costmodel/flops`. The clock is integer nanoseconds, ties break on a
//! monotone sequence number, and nothing reads the wall clock or an
//! unseeded RNG — runs are bit-reproducible
//! (`tests/sim_equivalence.rs` pins `SimResult == SimResult` across
//! runs).
//!
//! Layers, bottom up:
//!
//! - [`engine`] — the event queue, link resources, and op interpreter;
//! - [`collectives`] — collective → op-program lowering;
//! - [`schedule`] — [`StepSchedule`]: one MuonBP optimizer step (DP
//!   sync, slab-pipelined overlap, full/block TP phases, fault
//!   injection via `robust`'s `SlowLink`/`Straggler` vocabulary);
//! - [`calibrate`] — fit α–β link parameters from a recorded
//!   [`CommReport`](crate::comm::report::CommReport);
//! - [`sweep`] — tp × dp × period × sharding projection grids
//!   (`results/SIM_projection.json`).
//!
//! [`Simulated`] packages the simulator behind the
//! [`CostModel`](crate::costmodel::api::CostModel) trait, so every
//! closed-form charging site can swap in event-level pricing with
//! `--costmodel sim`. On uniform contention-free links the simulated
//! ring collectives reproduce the α–β closed form to nanosecond
//! rounding, and the simulated slab pipeline reproduces
//! [`overlap_pipeline`](crate::costmodel::netmodel::overlap_pipeline)
//! exactly — the closed form is the simulator's degenerate special
//! case.

pub mod calibrate;
pub mod collectives;
pub mod engine;
pub mod schedule;
pub mod sweep;

pub use calibrate::calibrate;
pub use engine::{
    ns_to_secs, secs_to_ns, LinkParams, Ns, Op, Proc, SimNet, SimResult,
};
pub use schedule::{
    ComputeModel, FabricLinks, ScheduleCfg, SimFaults, StepKind,
    StepSchedule, StepTimes,
};
pub use sweep::{run_sweep, SweepCfg};

use crate::comm::stats::CollectiveKind;
use crate::costmodel::api::CostModel;
use crate::costmodel::netmodel::{
    overlap_pipeline, NetModel, OverlapModel,
};

/// Event-level [`CostModel`]: collectives priced by replaying the ring
/// program through the engine, the overlapped step by replaying the
/// slab pipeline. Uniform links by default; `calibrate` feeds a fitted
/// [`NetModel`] in.
#[derive(Debug, Clone, Copy)]
pub struct Simulated {
    pub net: NetModel,
    /// Broadcast pipeline chunk, bytes.
    pub chunk_bytes: usize,
}

impl Simulated {
    pub fn uniform(net: NetModel) -> Simulated {
        Simulated { net, chunk_bytes: 1 << 20 }
    }
}

impl CostModel for Simulated {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn collective_time(
        &self,
        kind: CollectiveKind,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let mut ops: Vec<Vec<Op>> = vec![Vec::new(); n];
        let group: Vec<usize> = (0..n).collect();
        collectives::collective(
            &mut ops,
            &group,
            kind,
            payload_bytes as f64,
            self.chunk_bytes as f64,
        );
        let procs: Vec<Proc> = ops
            .into_iter()
            .enumerate()
            .map(|(r, ops)| Proc { rank: r, ops })
            .collect();
        ns_to_secs(engine::run(&SimNet::uniform(self.net), &procs).makespan)
    }

    fn overlapped_step_time(
        &self,
        comm_time: f64,
        compute_time: f64,
        n_slabs: usize,
    ) -> OverlapModel {
        let c = comm_time.max(0.0);
        let k = compute_time.max(0.0);
        if n_slabs <= 1 || c == 0.0 || k == 0.0 {
            return overlap_pipeline(c, k, n_slabs);
        }
        // Replay the slab pipeline: a comm lane fires a signal per slab,
        // a compute lane consumes one segment per signal. Uniform slabs
        // reproduce the closed form max(C, K) + min(C, K)/S exactly.
        let cs = secs_to_ns(c / n_slabs as f64);
        let ks = secs_to_ns(k / n_slabs as f64);
        let mut comm_ops = Vec::with_capacity(2 * n_slabs);
        let mut compute_ops = Vec::with_capacity(2 * n_slabs);
        for s in 0..n_slabs {
            comm_ops.push(Op::Compute(cs));
            comm_ops.push(Op::Fire { sig: s });
            compute_ops.push(Op::Wait { sig: s });
            compute_ops.push(Op::Compute(ks));
        }
        let procs = vec![
            Proc { rank: 0, ops: comm_ops },
            Proc { rank: 1, ops: compute_ops },
        ];
        let overlapped = ns_to_secs(
            engine::run(&SimNet::uniform(self.net), &procs).makespan,
        );
        let serial = c + k;
        let bubble_frac = if overlapped > 0.0 {
            (overlapped - c.max(k)).max(0.0) / overlapped
        } else {
            0.0
        };
        OverlapModel { serial, overlapped, bubble_frac }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::api::ClosedForm;
    use crate::mesh::StateSharding;

    #[test]
    fn simulated_matches_closed_form_on_grad_sync_kinds() {
        let net = NetModel::ib_hdr();
        let sim = Simulated::uniform(net);
        let cf = ClosedForm(net);
        for kind in [
            CollectiveKind::Barrier,
            CollectiveKind::AllReduce,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllGather,
        ] {
            for n in [2, 4, 8] {
                for bytes in [1usize << 12, 1 << 24] {
                    let s = sim.collective_time(kind, bytes, n);
                    let c = cf.collective_time(kind, bytes, n);
                    assert!(
                        (s - c).abs() <= 1e-3 * c.max(1e-9),
                        "{kind:?} n={n} b={bytes}: sim {s} vs cf {c}"
                    );
                }
            }
        }
        // And through the composite default methods too.
        for mode in [
            StateSharding::Replicated,
            StateSharding::Zero1,
            StateSharding::Zero2,
        ] {
            let s = sim.grad_sync_time(mode, 1 << 24, 8);
            let c = cf.grad_sync_time(mode, 1 << 24, 8);
            assert!((s - c).abs() <= 1e-3 * c, "{mode:?}: {s} vs {c}");
        }
    }

    #[test]
    fn simulated_overlap_reproduces_the_pipeline_formula() {
        let sim = Simulated::uniform(NetModel::ib_hdr());
        for (c, k, s) in [
            (0.008, 0.002, 4),
            (0.002, 0.008, 4),
            (0.005, 0.005, 8),
            (0.0, 0.005, 4),
            (0.005, 0.0, 4),
            (0.003, 0.007, 1),
        ] {
            let got = sim.overlapped_step_time(c, k, s);
            let want = overlap_pipeline(c, k, s);
            assert!(
                (got.overlapped - want.overlapped).abs() < 1e-6,
                "C={c} K={k} S={s}: {} vs {}",
                got.overlapped,
                want.overlapped
            );
            assert!((got.serial - want.serial).abs() < 1e-12);
            assert!((got.bubble_frac - want.bubble_frac).abs() < 1e-3);
        }
    }
}
