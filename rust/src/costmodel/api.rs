//! The unified cost-model surface: everything that prices a collective —
//! `Communicator` charging, `comm_report`'s overlap prediction, the
//! throughput model, the `muonbp sim` projections — goes through the
//! object-safe [`CostModel`] trait, selected on the CLI with
//! `--costmodel {closed-form,sim}`.
//!
//! Two implementations ship:
//! - [`ClosedForm`]: the α–β ring formulas from [`netmodel`] — free to
//!   evaluate, exact in the contention-free uniform-link regime.
//! - [`Simulated`](crate::costmodel::sim::Simulated): every query runs
//!   the discrete-event cluster simulator (`costmodel/sim`) and reads the
//!   virtual clock — identical numbers where the closed form is exact
//!   (ring all-reduce / reduce-scatter / all-gather on uniform links, see
//!   `tests/sim_equivalence.rs`), *different* numbers as soon as NIC
//!   serialization, contention or fault injection matter.
//!
//! [`netmodel`]: crate::costmodel::netmodel

use std::sync::Arc;

use crate::comm::stats::CollectiveKind;
use crate::costmodel::netmodel::{overlap_pipeline, NetModel, OverlapModel};
use crate::mesh::StateSharding;

/// Object-safe collective pricing. The composite predictions
/// (`grad_sync_time*`, `overlapped_step_time`) have default
/// implementations in terms of [`CostModel::collective_time`], so an
/// impl only has to price a single collective; impls may override the
/// composites when they can do better (the simulator replays the slab
/// pipeline event by event instead of using the closed-form bound).
pub trait CostModel: Send + Sync {
    /// CLI selector name (`closed-form`, `sim`).
    fn name(&self) -> &'static str;

    /// Time for one collective moving `payload_bytes` logical payload
    /// over `n` ranks.
    fn collective_time(
        &self,
        kind: CollectiveKind,
        payload_bytes: usize,
        n: usize,
    ) -> f64;

    /// One step's DP gradient sync over `payload_bytes` of matrix
    /// gradient at DP degree `dp`, per state-sharding mode — the same
    /// collective composition the coordinator issues (all-reduce /
    /// reduce-scatter + all-gather / reduce-scatter only).
    fn grad_sync_time(
        &self,
        mode: StateSharding,
        payload_bytes: usize,
        dp: usize,
    ) -> f64 {
        match mode {
            StateSharding::Replicated => self.collective_time(
                CollectiveKind::AllReduce,
                payload_bytes,
                dp,
            ),
            StateSharding::Zero1 => {
                self.collective_time(
                    CollectiveKind::ReduceScatter,
                    payload_bytes,
                    dp,
                ) + self.collective_time(
                    CollectiveKind::AllGather,
                    payload_bytes,
                    dp,
                )
            }
            StateSharding::Zero2 => self.collective_time(
                CollectiveKind::ReduceScatter,
                payload_bytes,
                dp,
            ),
        }
    }

    /// [`CostModel::grad_sync_time`] under the grouped
    /// (dp-groups-per-shard) topology: each TP block's DP sub-group
    /// syncs only its `payload_bytes / tp` rows on disjoint links.
    fn grad_sync_time_grouped(
        &self,
        mode: StateSharding,
        payload_bytes: usize,
        dp: usize,
        tp: usize,
    ) -> f64 {
        self.grad_sync_time(mode, payload_bytes / tp.max(1), dp)
    }

    /// Slab-pipeline overlap prediction for one optimizer step (see
    /// [`overlap_pipeline`] for the closed-form default).
    fn overlapped_step_time(
        &self,
        comm_time: f64,
        compute_time: f64,
        n_slabs: usize,
    ) -> OverlapModel {
        overlap_pipeline(comm_time, compute_time, n_slabs)
    }
}

/// The α–β ring closed form ([`NetModel`]) behind the trait. Delegates
/// every composite to the original `NetModel` methods so the trait
/// surface is provably identical to the pre-trait free functions.
#[derive(Debug, Clone, Copy)]
pub struct ClosedForm(pub NetModel);

impl CostModel for ClosedForm {
    fn name(&self) -> &'static str {
        "closed-form"
    }

    fn collective_time(
        &self,
        kind: CollectiveKind,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        self.0.collective_time(kind, payload_bytes, n)
    }

    fn grad_sync_time(
        &self,
        mode: StateSharding,
        payload_bytes: usize,
        dp: usize,
    ) -> f64 {
        self.0.grad_sync_time(mode, payload_bytes, dp)
    }

    fn grad_sync_time_grouped(
        &self,
        mode: StateSharding,
        payload_bytes: usize,
        dp: usize,
        tp: usize,
    ) -> f64 {
        self.0.grad_sync_time_grouped(mode, payload_bytes, dp, tp)
    }

    fn overlapped_step_time(
        &self,
        comm_time: f64,
        compute_time: f64,
        n_slabs: usize,
    ) -> OverlapModel {
        self.0.overlapped_step_time(comm_time, compute_time, n_slabs)
    }
}

/// Build the CLI-selected cost model over `net`'s link parameters.
pub fn by_name(
    name: &str,
    net: NetModel,
) -> anyhow::Result<Arc<dyn CostModel>> {
    Ok(match name {
        "closed-form" => Arc::new(ClosedForm(net)),
        "sim" => Arc::new(crate::costmodel::sim::Simulated::uniform(net)),
        other => anyhow::bail!(
            "unknown cost model '{other}' (expected 'closed-form' or 'sim')"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_matches_netmodel_exactly() {
        let net = NetModel::ib_hdr();
        let cf = ClosedForm(net);
        for kind in crate::comm::stats::ALL_KINDS {
            for n in [1, 2, 8] {
                assert_eq!(
                    cf.collective_time(kind, 1 << 22, n),
                    net.collective_time(kind, 1 << 22, n),
                    "{kind:?} n={n}"
                );
            }
        }
        for mode in [
            StateSharding::Replicated,
            StateSharding::Zero1,
            StateSharding::Zero2,
        ] {
            assert_eq!(
                cf.grad_sync_time(mode, 1 << 24, 8),
                net.grad_sync_time(mode, 1 << 24, 8)
            );
            assert_eq!(
                cf.grad_sync_time_grouped(mode, 1 << 24, 8, 4),
                net.grad_sync_time_grouped(mode, 1 << 24, 8, 4)
            );
        }
        let a = cf.overlapped_step_time(3.0, 5.0, 4);
        let b = net.overlapped_step_time(3.0, 5.0, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn default_composites_match_the_delegating_overrides() {
        // A minimal impl that only prices collectives must produce the
        // same composite predictions as ClosedForm's explicit
        // delegation — the default-method contract of the trait.
        struct Minimal(NetModel);
        impl CostModel for Minimal {
            fn name(&self) -> &'static str {
                "minimal"
            }
            fn collective_time(
                &self,
                kind: CollectiveKind,
                payload_bytes: usize,
                n: usize,
            ) -> f64 {
                self.0.collective_time(kind, payload_bytes, n)
            }
        }
        let net = NetModel::a100_nvlink();
        let min = Minimal(net);
        let cf = ClosedForm(net);
        for mode in [
            StateSharding::Replicated,
            StateSharding::Zero1,
            StateSharding::Zero2,
        ] {
            for dp in [2, 4, 8] {
                let a = min.grad_sync_time(mode, 1 << 24, dp);
                let b = cf.grad_sync_time(mode, 1 << 24, dp);
                assert!((a - b).abs() < 1e-15, "{mode:?} dp={dp}");
                let a = min.grad_sync_time_grouped(mode, 1 << 24, dp, 4);
                let b = cf.grad_sync_time_grouped(mode, 1 << 24, dp, 4);
                assert!((a - b).abs() < 1e-15, "{mode:?} dp={dp} grouped");
            }
        }
        let a = min.overlapped_step_time(8.0, 2.0, 4);
        let b = cf.overlapped_step_time(8.0, 2.0, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn by_name_selects_and_rejects() {
        let net = NetModel::ib_hdr();
        assert_eq!(by_name("closed-form", net).unwrap().name(), "closed-form");
        assert_eq!(by_name("sim", net).unwrap().name(), "sim");
        assert!(by_name("magic", net).is_err());
    }
}
