//! α–β collective timing: time = α·steps(kind, n) + bytes/bandwidth.
//!
//! Ring-algorithm step counts and effective volumes follow the standard
//! NCCL analysis. Presets model A100 NVLink (intra-node) and InfiniBand
//! HDR (inter-node) fabrics.

use crate::comm::stats::CollectiveKind;
use crate::mesh::StateSharding;

/// Simple α–β link model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Effective bandwidth in bytes/second.
    pub beta_bw: f64,
}

impl NetModel {
    /// A100 NVLink 3 (intra-node): ~300 GB/s effective bus, ~4 µs launch.
    pub fn a100_nvlink() -> NetModel {
        NetModel { alpha: 4e-6, beta_bw: 300e9 }
    }

    /// InfiniBand HDR inter-node: ~25 GB/s per GPU, ~10 µs.
    pub fn ib_hdr() -> NetModel {
        NetModel { alpha: 10e-6, beta_bw: 25e9 }
    }

    /// Idealized infinitely fast network (ablations).
    pub fn infinite() -> NetModel {
        NetModel { alpha: 0.0, beta_bw: f64::INFINITY }
    }

    /// Time for one collective moving `payload_bytes` logical payload over
    /// `n` ranks, using ring-algorithm effective wire volume.
    pub fn collective_time(
        &self,
        kind: CollectiveKind,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let s = payload_bytes as f64;
        let nf = n as f64;
        let (steps, wire_bytes) = match kind {
            CollectiveKind::Barrier => (nf - 1.0, 0.0),
            // Ring all-reduce: 2(n-1)/n of the buffer over 2(n-1) steps.
            CollectiveKind::AllReduce => {
                (2.0 * (nf - 1.0), 2.0 * s * (nf - 1.0) / nf)
            }
            // All-gather of total size s: each rank receives (n-1)/n of s.
            CollectiveKind::AllGather => ((nf - 1.0), s * (nf - 1.0) / nf),
            CollectiveKind::ReduceScatter => {
                ((nf - 1.0), s * (nf - 1.0) / nf)
            }
            // Root-rooted trees.
            CollectiveKind::Gather => ((nf - 1.0), s * (nf - 1.0) / nf),
            CollectiveKind::Scatter => ((nf - 1.0), s * (nf - 1.0) / nf),
            CollectiveKind::Broadcast => ((nf).log2().ceil(), s),
            CollectiveKind::AllToAll => ((nf - 1.0), s * (nf - 1.0) / nf),
        };
        self.alpha * steps + wire_bytes / self.beta_bw
    }

    /// Predicted wall-clock of one step's DP gradient sync over
    /// `payload_bytes` of matrix gradient at DP degree `dp`, per state-
    /// sharding mode. Under ring algorithms the ZeRO-1 pair (reduce-
    /// scatter + all-gather, `(n-1)` steps each) moves exactly the wire
    /// volume of the ring all-reduce (`2(n-1)` steps) — the ZeRO paper's
    /// "stage 1 is communication-free" claim — so the predicted times
    /// coincide; the win is the `1/dp` optimizer-state footprint and the
    /// strictly smaller per-rank payload traffic
    /// ([`grad_sync_bytes_per_rank`]).
    pub fn grad_sync_time(
        &self,
        mode: StateSharding,
        payload_bytes: usize,
        dp: usize,
    ) -> f64 {
        match mode {
            StateSharding::Replicated => {
                self.collective_time(CollectiveKind::AllReduce, payload_bytes, dp)
            }
            StateSharding::Zero1 => {
                self.collective_time(
                    CollectiveKind::ReduceScatter,
                    payload_bytes,
                    dp,
                ) + self.collective_time(
                    CollectiveKind::AllGather,
                    payload_bytes,
                    dp,
                )
            }
            // ZeRO-2: the gather round disappears entirely — the TP
            // phase consumes the reduce-scattered slices in place, so
            // the sync is the reduce-scatter alone: (n-1) steps and
            // s(n-1)/n wire, strictly half the ring all-reduce.
            StateSharding::Zero2 => self.collective_time(
                CollectiveKind::ReduceScatter,
                payload_bytes,
                dp,
            ),
        }
    }

    /// [`NetModel::grad_sync_time`] under the grouped
    /// (dp-groups-per-shard) topology: each TP block's DP sub-group
    /// syncs only that block's rows, so with `tp` equal shards the
    /// per-group payload is `payload_bytes / tp` and the groups run
    /// concurrently on disjoint links — predicted wall-clock is one
    /// group's time, exactly the full-replica time at `1/tp` payload.
    pub fn grad_sync_time_grouped(
        &self,
        mode: StateSharding,
        payload_bytes: usize,
        dp: usize,
        tp: usize,
    ) -> f64 {
        self.grad_sync_time(mode, payload_bytes / tp.max(1), dp)
    }
}

/// Predicted step-time split for the DAG-overlapped schedule
/// ([`NetModel::overlapped_step_time`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlapModel {
    /// Serial (barrier-schedule) step time: comm then compute, `C + K`.
    pub serial: f64,
    /// Overlapped step time: the longer of comm/compute hides the
    /// shorter, up to one pipeline-fill slab of the shorter resource.
    pub overlapped: f64,
    /// Fraction of the overlapped step spent with one resource idle
    /// (the pipeline bubble): `(overlapped - max(C, K)) / overlapped`,
    /// 0 when either side is zero (nothing to hide, or nothing hidden
    /// behind).
    pub bubble_frac: f64,
}

impl NetModel {
    /// Predicted wall-clock of one DAG-overlapped optimizer step whose
    /// DP sync takes `comm_time` seconds and whose TP-side compute
    /// (momentum + Newton–Schulz + assembly) takes `compute_time`
    /// seconds, pipelined at `n_slabs` row-slab granularity per matrix.
    ///
    /// The slab pipeline lets compute on slab `s` run while slab `s+1`
    /// is still syncing, so steady-state step time is the *max* of the
    /// two resources; the dependent side still pays a pipeline-fill
    /// bubble of one slab of the shorter resource before its first node
    /// becomes ready:
    ///
    /// ```text
    /// overlapped = max(C, K) + min(C, K) / n_slabs
    /// serial     = C + K                      (the barrier schedule)
    /// ```
    ///
    /// `n_slabs == 0` (or 1) means no pipelining: the schedule
    /// degenerates to serial. The model is deliberately coarse — it
    /// assumes slabs are uniform and both resources are fully busy in
    /// steady state — but it brackets the measured wall-clock
    /// (`DistMuon` records per-collective wall time next to the α–β sim
    /// time, surfaced by `comm_report`) well enough to tell whether the
    /// DAG executor is delivering its overlap.
    pub fn overlapped_step_time(
        &self,
        comm_time: f64,
        compute_time: f64,
        n_slabs: usize,
    ) -> OverlapModel {
        overlap_pipeline(comm_time, compute_time, n_slabs)
    }
}

/// The slab-pipeline overlap formula behind
/// [`NetModel::overlapped_step_time`] and the [`CostModel`] trait's
/// default — a free function because it depends on no link parameters.
/// The discrete-event simulator reproduces it exactly with uniform
/// slabs (`Simulated::overlapped_step_time` replays the pipeline event
/// by event), making this the degenerate special case of the simulator.
///
/// [`CostModel`]: crate::costmodel::api::CostModel
pub fn overlap_pipeline(
    comm_time: f64,
    compute_time: f64,
    n_slabs: usize,
) -> OverlapModel {
    let c = comm_time.max(0.0);
    let k = compute_time.max(0.0);
    let serial = c + k;
    let overlapped = if n_slabs <= 1 || c == 0.0 || k == 0.0 {
        serial
    } else {
        c.max(k) + c.min(k) / n_slabs as f64
    };
    let bubble_frac = if overlapped > 0.0 && c > 0.0 && k > 0.0 {
        (overlapped - c.max(k)) / overlapped
    } else {
        0.0
    };
    OverlapModel { serial, overlapped, bubble_frac }
}

/// Per-rank gradient-sync bytes for one optimizer step over
/// `payload_bytes` of matrix gradient at DP degree `dp`, under the
/// **reduced-data-delivery convention**: count the mean-gradient bytes a
/// rank must ingest into its optimizer-state path, plus the wire
/// exchange of the state it does not own. Be precise about what this is
/// NOT: under uniform ring wire accounting the two schedules move
/// *identical* volume — [`NetModel::grad_sync_time`] and the
/// `zero1_grad_sync_time_is_ring_neutral` test say so explicitly — so
/// this metric does not claim the NICs move fewer bytes. What it tracks
/// is the ZeRO-1 residency win made quantitative:
///
/// * `Replicated` (all-reduce): every rank contributes its full local
///   gradient and materializes the full mean — `2·s` (the ZeRO paper's
///   classic `2Ψ` per-rank accounting).
/// * `Zero1` (reduce-scatter + all-gather): the rank materializes only
///   the mean-gradient slice it owns (`s/dp` — it never consumes the
///   other `(dp-1)/dp`, which is the real saving), then ring-exchanges
///   momentum slices in the all-gather (sends its slice around the
///   ring, receives the `dp-1` others: `2·(dp-1)/dp·s`). Total
///   `s·(1/dp + 2(dp-1)/dp) = s·(2dp-1)/dp`, strictly below `2·s` for
///   every `dp ≥ 2` with the gap exactly the `s/dp` of reduced gradient
///   the rank no longer ingests — while the per-rank momentum footprint
///   shrinks as `1/dp`.
/// * `Zero2` (reduce-scatter only): the all-gather disappears — the TP
///   phase consumes the owned slice in place — leaving the ring
///   exchange of the `dp-1` slice contributions the rank does not keep:
///   `s·(dp-1)/dp`. The gap to ZeRO-1 is exactly the `s` of gathered
///   momentum the rank no longer re-ingests, so ZeRO-2 is below half
///   the replicated all-reduce at every `dp ≥ 2`.
pub fn grad_sync_bytes_per_rank(
    mode: StateSharding,
    payload_bytes: usize,
    dp: usize,
) -> f64 {
    if dp <= 1 {
        return 0.0; // a single-rank group moves nothing
    }
    let s = payload_bytes as f64;
    let d = dp as f64;
    match mode {
        StateSharding::Replicated => 2.0 * s,
        StateSharding::Zero1 => s * (1.0 / d + 2.0 * (d - 1.0) / d),
        StateSharding::Zero2 => s * (d - 1.0) / d,
    }
}

/// [`grad_sync_bytes_per_rank`] under the grouped (dp-groups-per-shard)
/// topology: a rank participates in exactly one TP block's DP sub-group
/// and syncs only that block's `payload_bytes / tp` rows — per-rank
/// bytes are exactly the full-replica figure divided by the shard
/// count, in every sharding mode.
pub fn grad_sync_bytes_per_rank_grouped(
    mode: StateSharding,
    payload_bytes: usize,
    dp: usize,
    tp: usize,
) -> f64 {
    grad_sync_bytes_per_rank(mode, payload_bytes / tp.max(1), dp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_ranks_is_free() {
        let m = NetModel::a100_nvlink();
        assert_eq!(
            m.collective_time(CollectiveKind::AllReduce, 1 << 20, 1),
            0.0
        );
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        // In the bandwidth-dominated regime time scales ~linearly.
        let m = NetModel::a100_nvlink();
        let t1 = m.collective_time(CollectiveKind::AllReduce, 1 << 26, 8);
        let t2 = m.collective_time(CollectiveKind::AllReduce, 1 << 30, 8);
        assert!(t2 > t1 * 10.0, "{t1} vs {t2}");
        // Small messages are latency-dominated: sublinear scaling.
        let s1 = m.collective_time(CollectiveKind::AllReduce, 64, 8);
        let s2 = m.collective_time(CollectiveKind::AllReduce, 64 * 16, 8);
        assert!(s2 < s1 * 2.0, "{s1} vs {s2}");
    }

    #[test]
    fn latency_dominates_small_messages() {
        let m = NetModel::ib_hdr();
        let t = m.collective_time(CollectiveKind::AllReduce, 64, 8);
        // 14 steps x 10us >> 64 bytes / 25GB/s
        assert!(t > 1e-4 && t < 2e-4, "{t}");
    }

    #[test]
    fn infinite_network_is_free() {
        let m = NetModel::infinite();
        assert_eq!(
            m.collective_time(CollectiveKind::AllGather, 1 << 30, 64),
            0.0
        );
    }

    #[test]
    fn zero1_grad_sync_strictly_cheaper_per_rank() {
        // The acceptance bound: for every dp >= 2 the ZeRO-1 schedule's
        // per-rank gradient-sync bytes are strictly below the replicated
        // all-reduce, and the gap widens toward s/dp as dp grows.
        let s = 1 << 20;
        for dp in [2, 4, 8, 64] {
            let ar =
                grad_sync_bytes_per_rank(StateSharding::Replicated, s, dp);
            let z1 = grad_sync_bytes_per_rank(StateSharding::Zero1, s, dp);
            assert!(z1 < ar, "dp={dp}: zero1 {z1} !< all-reduce {ar}");
            let want = s as f64 * (2.0 * dp as f64 - 1.0) / dp as f64;
            assert!((z1 - want).abs() < 1e-6, "dp={dp}: {z1} vs {want}");
            // The saving is exactly the (dp-1)/dp of the gradient the rank
            // no longer receives: ar - z1 = s/dp.
            assert!((ar - z1 - s as f64 / dp as f64).abs() < 1e-6);
        }
        // dp=1: nothing moves in either mode.
        for mode in [StateSharding::Replicated, StateSharding::Zero1] {
            assert_eq!(grad_sync_bytes_per_rank(mode, s, 1), 0.0);
        }
    }

    #[test]
    fn zero2_gap_to_zero1_is_exactly_the_gather() {
        // ZeRO-2 drops the all-gather round: per-rank bytes fall from
        // s(2dp-1)/dp to s(dp-1)/dp — the gap is exactly s (the full
        // gathered momentum the rank no longer re-ingests), at every
        // dp >= 2 and payload size.
        for s in [1usize << 10, 1 << 20, 3 * 1024 * 1024] {
            for dp in [2, 4, 8, 64] {
                let z1 =
                    grad_sync_bytes_per_rank(StateSharding::Zero1, s, dp);
                let z2 =
                    grad_sync_bytes_per_rank(StateSharding::Zero2, s, dp);
                assert!(
                    (z1 - z2 - s as f64).abs() < 1e-6,
                    "dp={dp} s={s}: z1 {z1} - z2 {z2} != s"
                );
                let want = s as f64 * (dp as f64 - 1.0) / dp as f64;
                assert!((z2 - want).abs() < 1e-6, "dp={dp}: {z2} vs {want}");
                // Strictly below half the replicated all-reduce.
                let ar = grad_sync_bytes_per_rank(
                    StateSharding::Replicated,
                    s,
                    dp,
                );
                assert!(z2 < ar / 2.0, "dp={dp}: {z2} !< {ar}/2");
            }
            assert_eq!(
                grad_sync_bytes_per_rank(StateSharding::Zero2, s, 1),
                0.0
            );
        }
    }

    #[test]
    fn zero2_sync_time_is_half_the_ring() {
        // RS-only: exactly half the ring all-reduce's steps and wire.
        let m = NetModel::ib_hdr();
        for dp in [2, 4, 8] {
            let t_ar =
                m.grad_sync_time(StateSharding::Replicated, 1 << 24, dp);
            let t_z2 = m.grad_sync_time(StateSharding::Zero2, 1 << 24, dp);
            assert!(
                (t_ar - 2.0 * t_z2).abs() < 1e-12 * t_ar.max(1.0),
                "dp={dp}: {t_ar} vs 2*{t_z2}"
            );
        }
    }

    #[test]
    fn grouped_topology_divides_by_shard_count() {
        // Per-TP-group DP sync charges exactly 1/tp of the full-replica
        // figure — bytes and predicted time — in every sharding mode.
        let m = NetModel::ib_hdr();
        let s = 1 << 24;
        for mode in [
            StateSharding::Replicated,
            StateSharding::Zero1,
            StateSharding::Zero2,
        ] {
            for tp in [1, 2, 4] {
                for dp in [2, 8] {
                    let full = grad_sync_bytes_per_rank(mode, s, dp);
                    let grouped =
                        grad_sync_bytes_per_rank_grouped(mode, s, dp, tp);
                    assert!(
                        (grouped - full / tp as f64).abs() < 1e-6,
                        "{mode:?} tp={tp} dp={dp}: {grouped} vs {full}/{tp}"
                    );
                    let tf = m.grad_sync_time(mode, s, dp);
                    let tg = m.grad_sync_time_grouped(mode, s, dp, tp);
                    let tw = m.grad_sync_time(mode, s / tp, dp);
                    assert!(
                        (tg - tw).abs() < 1e-15,
                        "{mode:?}: {tg} vs {tw}"
                    );
                    assert!(tg <= tf, "{mode:?}: grouped {tg} > full {tf}");
                }
            }
        }
    }

    #[test]
    fn zero1_grad_sync_time_is_ring_neutral() {
        // Under ring algorithms RS+AG move exactly the all-reduce wire
        // volume in the same 2(n-1) steps: ZeRO-1 is wall-clock neutral
        // (the ZeRO paper's claim), it wins on state + per-rank payload.
        let m = NetModel::ib_hdr();
        for dp in [2, 4, 8] {
            let t_ar =
                m.grad_sync_time(StateSharding::Replicated, 1 << 24, dp);
            let t_z1 = m.grad_sync_time(StateSharding::Zero1, 1 << 24, dp);
            assert!(
                (t_ar - t_z1).abs() < 1e-12 * t_ar.max(1.0),
                "dp={dp}: {t_ar} vs {t_z1}"
            );
        }
    }

    #[test]
    fn overlap_hides_the_shorter_resource() {
        let m = NetModel::ib_hdr();
        // Comm-bound: compute hides entirely except the fill bubble.
        let o = m.overlapped_step_time(8.0, 2.0, 4);
        assert_eq!(o.serial, 10.0);
        assert!((o.overlapped - (8.0 + 2.0 / 4.0)).abs() < 1e-12);
        assert!((o.bubble_frac - 0.5 / 8.5).abs() < 1e-12);
        assert!(o.overlapped < o.serial);
        // Compute-bound: symmetric.
        let o2 = m.overlapped_step_time(2.0, 8.0, 4);
        assert_eq!(o2.overlapped, o.overlapped);
        // More slabs shrink the bubble monotonically toward max(C, K).
        let o8 = m.overlapped_step_time(8.0, 2.0, 8);
        assert!(o8.overlapped < o.overlapped);
        assert!(o8.overlapped > 8.0);
    }

    #[test]
    fn overlap_degenerates_to_serial() {
        let m = NetModel::a100_nvlink();
        // No pipelining (0 or 1 slab) => barrier-equivalent.
        for n in [0, 1] {
            let o = m.overlapped_step_time(3.0, 5.0, n);
            assert_eq!(o.overlapped, o.serial);
            assert_eq!(o.bubble_frac, 0.0);
        }
        // One side zero: nothing to overlap, no bubble.
        let o = m.overlapped_step_time(0.0, 5.0, 4);
        assert_eq!(o.overlapped, 5.0);
        assert_eq!(o.bubble_frac, 0.0);
        let o = m.overlapped_step_time(5.0, 0.0, 4);
        assert_eq!(o.overlapped, 5.0);
        assert_eq!(o.bubble_frac, 0.0);
    }

    #[test]
    fn barrier_moves_no_bytes() {
        let m = NetModel::a100_nvlink();
        let t = m.collective_time(CollectiveKind::Barrier, 0, 4);
        assert!((t - 3.0 * 4e-6).abs() < 1e-12);
    }
}
