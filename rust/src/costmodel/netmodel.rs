//! α–β collective timing: time = α·steps(kind, n) + bytes/bandwidth.
//!
//! Ring-algorithm step counts and effective volumes follow the standard
//! NCCL analysis. Presets model A100 NVLink (intra-node) and InfiniBand
//! HDR (inter-node) fabrics.

use crate::comm::stats::CollectiveKind;

/// Simple α–β link model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Effective bandwidth in bytes/second.
    pub beta_bw: f64,
}

impl NetModel {
    /// A100 NVLink 3 (intra-node): ~300 GB/s effective bus, ~4 µs launch.
    pub fn a100_nvlink() -> NetModel {
        NetModel { alpha: 4e-6, beta_bw: 300e9 }
    }

    /// InfiniBand HDR inter-node: ~25 GB/s per GPU, ~10 µs.
    pub fn ib_hdr() -> NetModel {
        NetModel { alpha: 10e-6, beta_bw: 25e9 }
    }

    /// Idealized infinitely fast network (ablations).
    pub fn infinite() -> NetModel {
        NetModel { alpha: 0.0, beta_bw: f64::INFINITY }
    }

    /// Time for one collective moving `payload_bytes` logical payload over
    /// `n` ranks, using ring-algorithm effective wire volume.
    pub fn collective_time(
        &self,
        kind: CollectiveKind,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let s = payload_bytes as f64;
        let nf = n as f64;
        let (steps, wire_bytes) = match kind {
            CollectiveKind::Barrier => (nf - 1.0, 0.0),
            // Ring all-reduce: 2(n-1)/n of the buffer over 2(n-1) steps.
            CollectiveKind::AllReduce => {
                (2.0 * (nf - 1.0), 2.0 * s * (nf - 1.0) / nf)
            }
            // All-gather of total size s: each rank receives (n-1)/n of s.
            CollectiveKind::AllGather => ((nf - 1.0), s * (nf - 1.0) / nf),
            CollectiveKind::ReduceScatter => {
                ((nf - 1.0), s * (nf - 1.0) / nf)
            }
            // Root-rooted trees.
            CollectiveKind::Gather => ((nf - 1.0), s * (nf - 1.0) / nf),
            CollectiveKind::Scatter => ((nf - 1.0), s * (nf - 1.0) / nf),
            CollectiveKind::Broadcast => ((nf).log2().ceil(), s),
            CollectiveKind::AllToAll => ((nf - 1.0), s * (nf - 1.0) / nf),
        };
        self.alpha * steps + wire_bytes / self.beta_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_ranks_is_free() {
        let m = NetModel::a100_nvlink();
        assert_eq!(
            m.collective_time(CollectiveKind::AllReduce, 1 << 20, 1),
            0.0
        );
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        // In the bandwidth-dominated regime time scales ~linearly.
        let m = NetModel::a100_nvlink();
        let t1 = m.collective_time(CollectiveKind::AllReduce, 1 << 26, 8);
        let t2 = m.collective_time(CollectiveKind::AllReduce, 1 << 30, 8);
        assert!(t2 > t1 * 10.0, "{t1} vs {t2}");
        // Small messages are latency-dominated: sublinear scaling.
        let s1 = m.collective_time(CollectiveKind::AllReduce, 64, 8);
        let s2 = m.collective_time(CollectiveKind::AllReduce, 64 * 16, 8);
        assert!(s2 < s1 * 2.0, "{s1} vs {s2}");
    }

    #[test]
    fn latency_dominates_small_messages() {
        let m = NetModel::ib_hdr();
        let t = m.collective_time(CollectiveKind::AllReduce, 64, 8);
        // 14 steps x 10us >> 64 bytes / 25GB/s
        assert!(t > 1e-4 && t < 2e-4, "{t}");
    }

    #[test]
    fn infinite_network_is_free() {
        let m = NetModel::infinite();
        assert_eq!(
            m.collective_time(CollectiveKind::AllGather, 1 << 30, 64),
            0.0
        );
    }

    #[test]
    fn barrier_moves_no_bytes() {
        let m = NetModel::a100_nvlink();
        let t = m.collective_time(CollectiveKind::Barrier, 0, 4);
        assert!((t - 3.0 * 4e-6).abs() < 1e-12);
    }
}
