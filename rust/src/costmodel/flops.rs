//! FLOP accounting with the paper's own formulas.
//!
//! - fwd+bwd ≈ 6·N·T plus explicit attention-score terms (§2.2),
//! - full Newton–Schulz: 2mn + 2K(2nm² + m³) for m ≤ n,
//! - blocked NS on p×q blocks: 2(2mnq + mnq²/p) per step for q ≤ p (§3),
//! - Adam: 4·N, SGD-momentum: 2·N per step.

use crate::linalg::newton_schulz::ns_flops;

/// Symbolic model dimensions — the paper's Table 5 configurations live here
/// so throughput (Table 4) is computed at the *true* scales even though the
/// training proxies are smaller (DESIGN.md §1).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_seqs: usize,
    pub dp: usize,
    pub tp: usize,
}

impl ModelDims {
    fn new(
        name: &str,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        n_kv_heads: usize,
        seq_len: usize,
        batch_seqs: usize,
        dp: usize,
        tp: usize,
    ) -> ModelDims {
        // Llama-3-style SwiGLU hidden: 3.5·d rounded up to 256 (Llama 3 8B
        // uses 14336 = 3.5 x 4096).
        let d_ff = (d_model * 7 / 2 + 255) / 256 * 256;
        ModelDims {
            name: name.to_string(),
            vocab: 128_256, // Llama 3 tokenizer (paper §4.2)
            d_model,
            n_layers,
            n_heads,
            n_kv_heads,
            d_ff,
            seq_len,
            batch_seqs,
            dp,
            tp,
        }
    }

    /// Paper Table 5 rows (sequence length 8K).
    pub fn paper_960m() -> ModelDims {
        ModelDims::new("960M", 1536, 12, 16, 4, 8192, 128, 2, 4)
    }

    pub fn paper_1_2b() -> ModelDims {
        ModelDims::new("1.2B", 1792, 14, 16, 4, 8192, 128, 2, 4)
    }

    pub fn paper_8b() -> ModelDims {
        ModelDims::new("8B", 4096, 32, 32, 8, 8192, 256, 4, 8)
    }

    /// The Table 2 / Fig 11 model (160M, Dion codebase setting).
    pub fn paper_160m() -> ModelDims {
        let mut d = ModelDims::new("160M", 768, 12, 12, 12, 1024, 1024, 4, 2);
        d.vocab = 50_304; // GPT-2 tokenizer in the Dion codebase
        d
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim()
    }

    /// Hidden matrix parameter shapes per layer (the Muon-scoped params).
    pub fn layer_matrix_shapes(&self) -> Vec<(usize, usize)> {
        vec![
            (self.d_model, self.d_model),  // wq
            (self.d_model, self.kv_dim()), // wk
            (self.d_model, self.kv_dim()), // wv
            (self.d_model, self.d_model),  // wo
            (self.d_model, self.d_ff),     // w_gate
            (self.d_model, self.d_ff),     // w_up
            (self.d_ff, self.d_model),     // w_down
        ]
    }

    /// All hidden matrices in the model (layer shapes x n_layers).
    pub fn all_matrix_shapes(&self) -> Vec<(usize, usize)> {
        let per_layer = self.layer_matrix_shapes();
        let mut out = Vec::with_capacity(per_layer.len() * self.n_layers);
        for _ in 0..self.n_layers {
            out.extend(per_layer.iter().copied());
        }
        out
    }

    /// Total parameter count (incl. embeddings/head/norms).
    pub fn n_params(&self) -> usize {
        let hidden: usize = self
            .all_matrix_shapes()
            .iter()
            .map(|(m, n)| m * n)
            .sum();
        let embed = 2 * self.vocab * self.d_model;
        let norms = (2 * self.n_layers + 1) * self.d_model;
        hidden + embed + norms
    }

    /// Hidden (Muon-scoped) parameter count only.
    pub fn n_hidden_params(&self) -> usize {
        self.all_matrix_shapes().iter().map(|(m, n)| m * n).sum()
    }

    /// Tokens processed per optimizer step (global batch).
    pub fn tokens_per_step(&self) -> usize {
        self.batch_seqs * self.seq_len
    }

    pub fn world(&self) -> usize {
        self.dp * self.tp
    }
}

/// fwd+bwd FLOPs for one optimizer step: 6·N·T + attention-score terms
/// (12·L·T·s·d_head·n_heads = 12·L·T·s·d_model).
pub fn train_flops_per_step(dims: &ModelDims) -> f64 {
    let n = dims.n_params() as f64;
    let t = dims.tokens_per_step() as f64;
    let attn = 12.0
        * dims.n_layers as f64
        * t
        * dims.seq_len as f64
        * dims.d_model as f64;
    6.0 * n * t + attn
}

/// Adam optimizer step FLOPs (4 per parameter, §2.2).
pub fn adam_flops(n_params: usize) -> f64 {
    4.0 * n_params as f64
}

/// Full-matrix NS FLOPs over all hidden matrices.
pub fn full_ns_flops(dims: &ModelDims, ns_steps: usize) -> f64 {
    dims.all_matrix_shapes()
        .iter()
        .map(|&(m, n)| ns_flops(m, n, ns_steps))
        .sum()
}

/// Blocked NS FLOPs: each (m, n) matrix split into an r x c grid and each
/// block orthogonalized independently. Matches the paper's §3 reduction:
/// 2(2pq² + q³)·(mn/pq) per NS step for blocks p x q (q ≤ p).
pub fn block_ns_flops(
    dims: &ModelDims,
    grid_of: impl Fn(usize, usize) -> (usize, usize),
    ns_steps: usize,
) -> f64 {
    dims.all_matrix_shapes()
        .iter()
        .map(|&(m, n)| {
            let (r, c) = grid_of(m, n);
            let (bm, bn) = (m / r.max(1), n / c.max(1));
            (r * c) as f64 * ns_flops(bm.max(1), bn.max(1), ns_steps)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_names() {
        // Sanity: each preset's parameter count is near its nameplate.
        // Nameplate bands are loose: the paper's counts depend on details
        // (tied embeddings, exact d_ff) Table 5 does not pin down.
        let cases = [
            (ModelDims::paper_960m(), 0.6e9, 1.2e9),
            (ModelDims::paper_1_2b(), 0.9e9, 1.6e9),
            (ModelDims::paper_8b(), 7.0e9, 9.5e9),
            (ModelDims::paper_160m(), 0.1e9, 0.3e9),
        ];
        for (d, lo, hi) in cases {
            let n = d.n_params() as f64;
            assert!(n > lo && n < hi, "{}: {n}", d.name);
        }
    }

    #[test]
    fn train_flops_dominated_by_6nt() {
        let d = ModelDims::paper_960m();
        let f = train_flops_per_step(&d);
        let base = 6.0 * d.n_params() as f64 * d.tokens_per_step() as f64;
        assert!(f > base && f < base * 1.6, "{f} vs {base}");
    }

    #[test]
    fn paper_block_speedup_examples() {
        // §3: Llama 3 405B MLP matrices with 8-way TP give ~2.36x (up-proj)
        // and ~9.06x (down-proj) per-NS-step speedup vs full
        // orthogonalization. Both splits act on the *stored last dim*:
        // up (16384 x 53248) -> blocks 16384 x 6656; down (53248 x 16384)
        // -> blocks 53248 x 2048.
        let per_step = |m: usize, n: usize| {
            let (m, n) = if m <= n { (m, n) } else { (n, m) };
            2.0 * (2.0 * n as f64 * (m as f64).powi(2) + (m as f64).powi(3))
        };
        let speed_up =
            per_step(16384, 53248) / (8.0 * per_step(16384, 53248 / 8));
        assert!((speed_up - 2.36).abs() < 0.15, "up {speed_up}");
        let speed_down =
            per_step(53248, 16384) / (8.0 * per_step(53248, 16384 / 8));
        assert!((speed_down - 9.06).abs() < 0.6, "down {speed_down}");
    }

    #[test]
    fn block_ns_cheaper_than_full() {
        let d = ModelDims::paper_960m();
        let full = full_ns_flops(&d, 5);
        let blocked = block_ns_flops(&d, |_, _| (1, 4), 5);
        assert!(blocked < full, "{blocked} vs {full}");
    }

    #[test]
    fn adam_flops_linear() {
        assert_eq!(adam_flops(10), 40.0);
    }
}
