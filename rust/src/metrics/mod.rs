//! Experiment metrics: named series, CSV/JSON export, loss/ppl summaries.
//!
//! Every bench emits its table/figure through this module so the artifacts
//! under `results/` are uniform and EXPERIMENTS.md can quote them.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::utils::json::Json;

/// One (step, value) series plus optional wall-clock per point.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub steps: Vec<usize>,
    pub values: Vec<f64>,
    pub wall: Vec<f64>,
}

impl Series {
    pub fn push(&mut self, step: usize, value: f64) {
        self.steps.push(step);
        self.values.push(value);
    }

    pub fn push_timed(&mut self, step: usize, value: f64, wall: f64) {
        self.push(step, value);
        self.wall.push(wall);
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn last(&self) -> Option<f64> {
        self.values.last().copied()
    }

    /// First wall-clock time at which the series dips below `target`
    /// (the paper's "time to reach a target ppl" metric in Fig 3).
    pub fn time_to_reach(&self, target: f64) -> Option<f64> {
        self.values
            .iter()
            .zip(&self.wall)
            .find(|(v, _)| **v <= target)
            .map(|(_, w)| *w)
    }
}

/// A recorder holding named series for one experiment run.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    pub series: BTreeMap<String, Series>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn push(&mut self, name: &str, step: usize, value: f64) {
        self.series.entry(name.to_string()).or_default().push(step, value);
    }

    pub fn push_timed(&mut self, name: &str, step: usize, value: f64, wall: f64) {
        self.series
            .entry(name.to_string())
            .or_default()
            .push_timed(step, value, wall);
    }

    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// CSV with one row per (series, step).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,step,value,wall_time\n");
        for (name, s) in &self.series {
            for (i, (&step, &v)) in
                s.steps.iter().zip(&s.values).enumerate()
            {
                let w = s
                    .wall
                    .get(i)
                    .map(|w| format!("{w}"))
                    .unwrap_or_default();
                let _ = writeln!(out, "{name},{step},{v},{w}");
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.series
                .iter()
                .map(|(name, s)| {
                    (
                        name.clone(),
                        Json::obj(vec![
                            (
                                "steps",
                                Json::Arr(
                                    s.steps
                                        .iter()
                                        .map(|&x| Json::num(x as f64))
                                        .collect(),
                                ),
                            ),
                            (
                                "values",
                                Json::Arr(
                                    s.values
                                        .iter()
                                        .map(|&x| Json::num(x))
                                        .collect(),
                                ),
                            ),
                            (
                                "wall",
                                Json::Arr(
                                    s.wall
                                        .iter()
                                        .map(|&x| Json::num(x))
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        )
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// loss -> perplexity.
pub fn ppl(loss: f64) -> f64 {
    loss.exp()
}

/// Render an aligned text table (benches print these next to the paper's).
pub fn render_table(
    title: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = format!("== {title} ==\n");
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_summaries() {
        let mut s = Series::default();
        s.push_timed(0, 5.0, 0.0);
        s.push_timed(10, 3.0, 1.0);
        s.push_timed(20, 4.0, 2.0);
        assert_eq!(s.min(), 3.0);
        assert_eq!(s.last(), Some(4.0));
        assert_eq!(s.time_to_reach(3.5), Some(1.0));
        assert_eq!(s.time_to_reach(1.0), None);
    }

    #[test]
    fn csv_format() {
        let mut r = Recorder::new();
        r.push("loss", 0, 1.5);
        r.push("loss", 1, 1.25);
        let csv = r.to_csv();
        assert!(csv.starts_with("series,step,value,wall_time\n"));
        assert!(csv.contains("loss,1,1.25,"));
    }

    #[test]
    fn json_roundtrips() {
        let mut r = Recorder::new();
        r.push_timed("a", 0, 2.0, 0.1);
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed.req("a").unwrap().req("values").unwrap().as_arr().unwrap()
                [0],
            Json::Num(2.0)
        );
    }

    #[test]
    fn ppl_conversion() {
        assert!((ppl(0.0) - 1.0).abs() < 1e-12);
        assert!((ppl(2.0) - 2.0f64.exp()).abs() < 1e-12);
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            "T",
            &["method", "val"],
            &[
                vec!["Muon".into(), "15.33".into()],
                vec!["MuonBP".into(), "15.12".into()],
            ],
        );
        assert!(t.contains("== T =="));
        assert!(t.contains("Muon"));
        assert!(t.lines().count() >= 4);
    }
}
