//! Fault-tolerance primitives: structured step failures, anomaly
//! policies, deterministic fault injection, and numeric guardrails.
//!
//! The paper's stability argument (§3: periodic full orthogonalization
//! exists "to maintain training stability at scale") presumes the step
//! itself survives long enough to reach the next full step. This module
//! supplies the failure model the rest of the crate threads through:
//!
//! - [`StepError`] — what one distributed optimizer step can report
//!   instead of panicking or deadlocking. `Copy` on purpose: the
//!   coordinator records it through a preallocated slot on the
//!   zero-allocation steady-state path.
//! - [`AnomalyPolicy`] — what the caller does about it
//!   (`--on-anomaly {abort,skip-step,escalate-full-orth}`). The
//!   escalation path is the paper-grounded degradation: a blockwise step
//!   whose block Newton–Schulz misbehaves is retried as a
//!   full-orthogonalization step with the full-step stepsize.
//! - [`FaultPlan`] — deterministic fault injection (NaN gradients at a
//!   chosen step, a rank panicking in a chosen phase, a straggler
//!   delay), so every recovery path is exercised by tests rather than
//!   trusted.
//! - Guardrail helpers — non-finite gradient detection and the
//!   NS-divergence bound check on orthogonalized output.

use std::fmt;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Structured failure from one distributed optimizer step. The step's
/// atomicity contract guarantees that whenever `try_step` returns one of
/// these, parameters and momentum are bit-identical to their pre-step
/// values.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepError {
    /// A gradient tensor contained NaN/Inf (detected before any state
    /// was touched).
    NonFiniteGrad { param: usize },
    /// The Newton–Schulz output for this parameter violated the
    /// spectral-norm-derived Frobenius bound (or went non-finite).
    NsDiverged { param: usize, norm: f32, bound: f32 },
    /// A rank panicked in the given phase of the step schedule
    /// (0 = DP grad sync, 1 = TP fanout, 2 = leader full-orth,
    /// 3 = reassembly).
    RankPanicked { rank: usize, phase: u8 },
    /// This rank was released from a poisoned barrier: a *peer* failed
    /// mid-collective and poisoned the phase barrier to free all
    /// waiters.
    Poisoned,
    /// A collective missed its deadline: `rank` is the peer the
    /// collective was still waiting on when the deadline expired.
    Timeout { rank: usize, phase: u8, elapsed_ms: u64 },
    /// A peer is confirmed dead (heartbeat loss or a dropped
    /// connection), not merely slow.
    PeerDead { rank: usize },
}

impl fmt::Display for StepError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            StepError::NonFiniteGrad { param } => {
                write!(f, "non-finite gradient in param {param}")
            }
            StepError::NsDiverged { param, norm, bound } => write!(
                f,
                "newton-schulz diverged on param {param}: \
                 ||U||_F = {norm} exceeds bound {bound}"
            ),
            StepError::RankPanicked { rank, phase } => {
                write!(f, "rank {rank} panicked in phase {phase}")
            }
            StepError::Poisoned => {
                write!(f, "released from a poisoned barrier (a peer failed)")
            }
            StepError::Timeout { rank, phase, elapsed_ms } => write!(
                f,
                "collective deadline expired in phase {phase} after \
                 {elapsed_ms}ms waiting on rank {rank}"
            ),
            StepError::PeerDead { rank } => {
                write!(f, "peer rank {rank} is dead (heartbeat lost)")
            }
        }
    }
}

impl std::error::Error for StepError {}

impl StepError {
    /// Distinct process exit code per variant, in a reserved 41..=46
    /// band, so a supervisor can tell a timed-out collective from a
    /// diverged Newton–Schulz from a panicked rank without parsing
    /// stderr. (1 stays "generic failure"; 90/124 belong to ci.sh.)
    pub fn exit_code(&self) -> i32 {
        match self {
            StepError::NonFiniteGrad { .. } => 41,
            StepError::NsDiverged { .. } => 42,
            StepError::RankPanicked { .. } => 43,
            StepError::Poisoned => 44,
            StepError::Timeout { .. } => 45,
            StepError::PeerDead { .. } => 46,
        }
    }
}

/// What to do when a numeric guardrail trips during training.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AnomalyPolicy {
    /// Stop the run with a structured error (no state corrupted).
    #[default]
    Abort,
    /// Drop the batch: leave params/momentum untouched, count the skip,
    /// continue with the next batch.
    SkipStep,
    /// Paper-grounded degradation: retry a misbehaving *block* step as a
    /// full-orthogonalization step with the full-step stepsize; other
    /// failures fall back to skip-step semantics.
    EscalateFullOrth,
    /// Comm-avoiding degradation (escalate-full-orth in reverse): when a
    /// *full* step's gather/scatter times out, commit the step blockwise
    /// with the blockwise stepsize (`lr * eta_block_ratio`, the §3.2
    /// two-stepsize rule) — block steps need no gather/scatter, so the
    /// run keeps making progress comm-free. A make-up full
    /// orthogonalization is scheduled on the next healthy step.
    DegradeBlock,
}

impl AnomalyPolicy {
    pub fn parse(s: &str) -> Result<AnomalyPolicy> {
        Ok(match s {
            "abort" => AnomalyPolicy::Abort,
            "skip-step" => AnomalyPolicy::SkipStep,
            "escalate-full-orth" => AnomalyPolicy::EscalateFullOrth,
            "degrade-block" => AnomalyPolicy::DegradeBlock,
            other => bail!(
                "unknown anomaly policy '{other}' \
                 (want abort|skip-step|escalate-full-orth|degrade-block)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AnomalyPolicy::Abort => "abort",
            AnomalyPolicy::SkipStep => "skip-step",
            AnomalyPolicy::EscalateFullOrth => "escalate-full-orth",
            AnomalyPolicy::DegradeBlock => "degrade-block",
        }
    }
}

/// Panic a chosen rank in a chosen phase of a chosen optimizer attempt.
/// `attempt` is 1-based: the k-th `try_step` call (failed attempts
/// count, so an injected fault fires exactly once).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhasePanic {
    pub attempt: u64,
    pub rank: usize,
    pub phase: u8,
}

impl PhasePanic {
    /// Parse `"attempt:rank:phase"` (e.g. `--fault-panic 3:1:0`).
    pub fn parse(s: &str) -> Result<PhasePanic> {
        let parts: Vec<&str> = s.split(':').collect();
        let [a, r, p] = parts[..] else {
            bail!("bad fault spec '{s}' (want attempt:rank:phase)");
        };
        let panic = PhasePanic {
            attempt: a.parse()?,
            rank: r.parse()?,
            phase: p.parse()?,
        };
        if panic.phase > 3 {
            bail!("bad fault phase {} (schedule has phases 0..=3)", panic.phase);
        }
        Ok(panic)
    }
}

/// Delay a chosen rank by `delay_ms` at the start of phase 0 of a chosen
/// attempt (a straggler, not a failure: the step must still be
/// bit-identical to an undelayed run).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Straggler {
    pub attempt: u64,
    pub rank: usize,
    pub delay_ms: u64,
}

impl Straggler {
    /// Parse `"attempt:rank:delay_ms"` (e.g. `--fault-straggle 2:1:50`).
    pub fn parse(s: &str) -> Result<Straggler> {
        let parts: Vec<&str> = s.split(':').collect();
        let [a, r, d] = parts[..] else {
            bail!("bad straggler spec '{s}' (want attempt:rank:delay_ms)");
        };
        Ok(Straggler {
            attempt: a.parse()?,
            rank: r.parse()?,
            delay_ms: d.parse()?,
        })
    }
}

/// Make a chosen rank vanish mid-collective on a chosen attempt: the
/// transport marks the peer dead and the collective fails with
/// `PeerDead`/`Timeout` instead of completing. Injected at the
/// Transport layer (`comm::transport::ArmedFault`), not a thread sleep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DropRank {
    pub attempt: u64,
    pub rank: usize,
}

impl DropRank {
    /// Parse `"attempt:rank"` (e.g. `--fault-drop-rank 2:1`).
    pub fn parse(s: &str) -> Result<DropRank> {
        let parts: Vec<&str> = s.split(':').collect();
        let [a, r] = parts[..] else {
            bail!("bad drop-rank spec '{s}' (want attempt:rank)");
        };
        Ok(DropRank { attempt: a.parse()?, rank: r.parse()? })
    }
}

/// Delay a chosen rank's transport sends by `delay_ms` on a chosen
/// attempt — a slow *link*, injected inside the Transport's collective
/// path (where a deadline can catch it), unlike [`Straggler`] which
/// sleeps the rank's thread before it enters the collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlowLink {
    pub attempt: u64,
    pub rank: usize,
    pub delay_ms: u64,
}

impl SlowLink {
    /// Parse `"attempt:rank:delay_ms"` (e.g. `--fault-slow-link 1:1:500`).
    pub fn parse(s: &str) -> Result<SlowLink> {
        let parts: Vec<&str> = s.split(':').collect();
        let [a, r, d] = parts[..] else {
            bail!("bad slow-link spec '{s}' (want attempt:rank:delay_ms)");
        };
        Ok(SlowLink {
            attempt: a.parse()?,
            rank: r.parse()?,
            delay_ms: d.parse()?,
        })
    }
}

/// Deterministic fault injection plan. Default is inert; every injected
/// fault is keyed so it fires exactly once, making the recovery paths
/// reproducible in tests and from the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultPlan {
    /// Inject NaN into the gradients at this 0-based *trainer* step.
    pub nan_grad_step: Option<u64>,
    /// Panic a rank in a phase of a 1-based optimizer attempt.
    pub panic_at: Option<PhasePanic>,
    /// Delay a rank in phase 0 of a 1-based optimizer attempt.
    pub straggler: Option<Straggler>,
    /// Drop a rank mid-collective on a 1-based optimizer attempt.
    pub drop_rank: Option<DropRank>,
    /// Slow a rank's transport sends on a 1-based optimizer attempt.
    pub slow_link: Option<SlowLink>,
}

impl FaultPlan {
    pub fn is_inert(&self) -> bool {
        self.nan_grad_step.is_none()
            && self.panic_at.is_none()
            && self.straggler.is_none()
            && self.drop_rank.is_none()
            && self.slow_link.is_none()
    }

    /// Should the trainer corrupt this step's gradients?
    pub fn maybe_nan(&self, step: u64) -> bool {
        self.nan_grad_step == Some(step)
    }

    /// Called from inside the step schedule; panics iff this
    /// (attempt, rank, phase) matches the plan.
    pub fn maybe_panic(&self, attempt: u64, rank: usize, phase: u8) {
        if let Some(p) = self.panic_at {
            if p.attempt == attempt && p.rank == rank && p.phase == phase {
                panic!(
                    "injected fault: rank {rank} phase {phase} \
                     attempt {attempt}"
                );
            }
        }
    }

    /// Called at the start of phase 0; sleeps iff this (attempt, rank)
    /// matches the plan.
    pub fn maybe_straggle(&self, attempt: u64, rank: usize) {
        if let Some(s) = self.straggler {
            if s.attempt == attempt && s.rank == rank {
                std::thread::sleep(std::time::Duration::from_millis(
                    s.delay_ms,
                ));
            }
        }
    }
}

/// Index of the first gradient tensor with a non-finite entry, if any
/// (f64-accumulated Frobenius norm, so NaN/Inf anywhere propagates).
pub fn first_non_finite(grads: &[Tensor]) -> Option<usize> {
    grads.iter().position(|g| !g.frobenius().is_finite())
}

/// Corrupt the gradients in place (the `nan_grad_step` injection): one
/// NaN in the first non-empty tensor is enough to trip every downstream
/// guardrail.
pub fn inject_nan(grads: &mut [Tensor]) {
    for g in grads.iter_mut() {
        if g.numel() > 0 {
            g.data_mut()[0] = f32::NAN;
            return;
        }
    }
}

/// Frobenius-norm bound for a *healthy* Newton–Schulz output of shape
/// (m, n): the Jordan-coefficient iteration keeps singular values in a
/// band below ~1.4 (pinned by `jordan_coeffs_band_property`), so
/// ||U||_F <= sigma_max * sqrt(min(m, n)). The factor 2.0 leaves margin
/// over the band so only genuine divergence (blown-up or non-finite
/// iterates) trips the check.
pub fn ns_divergence_bound(m: usize, n: usize) -> f32 {
    2.0 * (m.min(n).max(1) as f32).sqrt()
}

/// NS-divergence guardrail on an orthogonalized output `u`, with the
/// caller's post-NS scaling (RMS matching) folded into the bound.
/// Returns `Err((norm, bound))` when the output is non-finite or
/// exceeds the scaled bound.
pub fn check_ns_output(u: &Tensor, scale: f32) -> std::result::Result<(), (f32, f32)> {
    let bound = ns_divergence_bound(u.m(), u.n()) * scale.abs();
    let norm = u.frobenius();
    if !norm.is_finite() || norm > bound {
        Err((norm, bound))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::rng::Rng;

    #[test]
    fn step_error_display_and_copy() {
        let e = StepError::NsDiverged { param: 3, norm: 9.0, bound: 4.0 };
        let copy = e; // Copy: usable through a preallocated slot
        assert_eq!(e, copy);
        assert!(format!("{e}").contains("param 3"));
        assert!(format!("{}", StepError::Poisoned).contains("poisoned"));
        assert!(format!(
            "{}",
            StepError::RankPanicked { rank: 2, phase: 1 }
        )
        .contains("rank 2"));
        assert!(format!(
            "{}",
            StepError::Timeout { rank: 1, phase: 0, elapsed_ms: 120 }
        )
        .contains("rank 1"));
        assert!(
            format!("{}", StepError::PeerDead { rank: 3 }).contains("rank 3")
        );
    }

    #[test]
    fn exit_codes_are_distinct_and_banded() {
        let errs = [
            StepError::NonFiniteGrad { param: 0 },
            StepError::NsDiverged { param: 0, norm: 1.0, bound: 0.5 },
            StepError::RankPanicked { rank: 0, phase: 0 },
            StepError::Poisoned,
            StepError::Timeout { rank: 0, phase: 0, elapsed_ms: 1 },
            StepError::PeerDead { rank: 0 },
        ];
        let codes: Vec<i32> = errs.iter().map(|e| e.exit_code()).collect();
        for (i, a) in codes.iter().enumerate() {
            assert!((41..=46).contains(a), "{a} outside the reserved band");
            for b in &codes[i + 1..] {
                assert_ne!(a, b, "exit codes must be distinct");
            }
        }
    }

    #[test]
    fn anomaly_policy_parse_roundtrip() {
        for p in [
            AnomalyPolicy::Abort,
            AnomalyPolicy::SkipStep,
            AnomalyPolicy::EscalateFullOrth,
            AnomalyPolicy::DegradeBlock,
        ] {
            assert_eq!(AnomalyPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(AnomalyPolicy::parse("retry-harder").is_err());
        assert_eq!(AnomalyPolicy::default(), AnomalyPolicy::Abort);
    }

    #[test]
    fn fault_plan_parse_and_keys() {
        let p = PhasePanic::parse("3:1:2").unwrap();
        assert_eq!(p, PhasePanic { attempt: 3, rank: 1, phase: 2 });
        assert!(PhasePanic::parse("3:1").is_err());
        assert!(PhasePanic::parse("3:1:9").is_err());
        assert!(PhasePanic::parse("x:1:2").is_err());
        let s = Straggler::parse("2:0:15").unwrap();
        assert_eq!(s, Straggler { attempt: 2, rank: 0, delay_ms: 15 });

        let d = DropRank::parse("2:1").unwrap();
        assert_eq!(d, DropRank { attempt: 2, rank: 1 });
        assert!(DropRank::parse("2").is_err());
        assert!(DropRank::parse("2:1:0").is_err());
        let l = SlowLink::parse("1:1:500").unwrap();
        assert_eq!(l, SlowLink { attempt: 1, rank: 1, delay_ms: 500 });
        assert!(SlowLink::parse("1:1").is_err());

        let plan = FaultPlan {
            nan_grad_step: Some(4),
            panic_at: Some(p),
            straggler: Some(s),
            drop_rank: Some(d),
            slow_link: Some(l),
        };
        assert!(!plan.is_inert());
        assert!(FaultPlan::default().is_inert());
        assert!(!FaultPlan { drop_rank: Some(d), ..Default::default() }
            .is_inert());
        assert!(!FaultPlan { slow_link: Some(l), ..Default::default() }
            .is_inert());
        assert!(plan.maybe_nan(4));
        assert!(!plan.maybe_nan(3));
        // Non-matching keys are no-ops (would panic/sleep otherwise).
        plan.maybe_panic(3, 1, 1);
        plan.maybe_panic(2, 1, 2);
        plan.maybe_straggle(2, 1);
    }

    #[test]
    #[should_panic(expected = "injected fault")]
    fn fault_plan_panics_on_exact_match() {
        let plan = FaultPlan {
            panic_at: Some(PhasePanic { attempt: 1, rank: 0, phase: 0 }),
            ..Default::default()
        };
        plan.maybe_panic(1, 0, 0);
    }

    #[test]
    fn non_finite_detection_and_injection() {
        let mut grads =
            vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[3])];
        assert_eq!(first_non_finite(&grads), None);
        inject_nan(&mut grads);
        assert_eq!(first_non_finite(&grads), Some(0));
        grads[0] = Tensor::zeros(&[2, 2]);
        grads[1].data_mut()[1] = f32::INFINITY;
        assert_eq!(first_non_finite(&grads), Some(1));
    }

    #[test]
    fn ns_bound_accepts_healthy_rejects_diverged() {
        // A healthy NS output has singular values <= ~1.4; an orthonormal
        // matrix (sigma = 1) sits well inside the bound.
        let mut rng = Rng::new(7);
        let u = crate::linalg::newton_schulz::newton_schulz(
            &Tensor::randn(&[12, 6], 1.0, &mut rng),
            5,
            crate::linalg::newton_schulz::NsCoeffs::jordan(),
        );
        assert!(check_ns_output(&u, 1.0).is_ok());
        // The caller's RMS scaling is folded into the bound.
        let mut scaled = u.clone();
        scaled.scale(3.0);
        assert!(check_ns_output(&scaled, 3.0).is_ok());
        assert!(check_ns_output(&scaled, 1.0).is_err());
        // Blow-up and non-finite outputs both trip it.
        let mut big = Tensor::zeros(&[12, 6]);
        big.add_scalar(10.0);
        assert!(check_ns_output(&big, 1.0).is_err());
        let mut nan = Tensor::zeros(&[12, 6]);
        nan.data_mut()[0] = f32::NAN;
        let (norm, _) = check_ns_output(&nan, 1.0).unwrap_err();
        assert!(!norm.is_finite());
    }
}
