//! Tensor sharding: extracting/assembling the exact submatrix blocks that
//! model parallelism places on each device (paper §3).
//!
//! `ShardSpec` binds a parameter's layout + TP degree to concrete block
//! coordinates; `shard`/`unshard` are the gather/scatter data movement the
//! coordinator performs on *full* orthogonalization steps.

use crate::mesh::Layout;
use crate::tensor::Tensor;

/// Even split of `dim` into `n` shards; trailing shards absorb remainder.
/// Returns [start, end) of shard `idx`.
pub fn shard_range(dim: usize, n: usize, idx: usize) -> (usize, usize) {
    assert!(idx < n, "shard index {idx} out of {n}");
    let base = dim / n;
    let rem = dim % n;
    // First `rem` shards get one extra element (balanced partition).
    let start = idx * base + idx.min(rem);
    let extra = if idx < rem { 1 } else { 0 };
    (start, start + base + extra)
}

/// Concrete block partition of one matrix parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub rows: usize,
    pub cols: usize,
    pub m: usize,
    pub n: usize,
}

impl ShardSpec {
    pub fn new(layout: Layout, tp: usize, m: usize, n: usize) -> ShardSpec {
        let (rows, cols) = layout.block_grid(tp, m, n);
        ShardSpec { rows, cols, m, n }
    }

    pub fn num_blocks(&self) -> usize {
        self.rows * self.cols
    }

    /// (row-block, col-block) coordinates for flat block id.
    pub fn coords(&self, idx: usize) -> (usize, usize) {
        (idx / self.cols, idx % self.cols)
    }

    /// Row/col ranges of block `idx`.
    pub fn ranges(&self, idx: usize) -> ((usize, usize), (usize, usize)) {
        let (i, j) = self.coords(idx);
        (shard_range(self.m, self.rows, i), shard_range(self.n, self.cols, j))
    }

    /// Shape of block `idx`.
    pub fn block_shape(&self, idx: usize) -> (usize, usize) {
        let ((r0, r1), (c0, c1)) = self.ranges(idx);
        (r1 - r0, c1 - c0)
    }

    /// Bytes of one block (f32).
    pub fn block_bytes(&self, idx: usize) -> usize {
        let (bm, bn) = self.block_shape(idx);
        bm * bn * 4
    }
}

/// Extract block `idx` of `t` (a device's local shard).
pub fn shard(t: &Tensor, spec: &ShardSpec, idx: usize) -> Tensor {
    assert_eq!((t.m(), t.n()), (spec.m, spec.n), "spec/tensor mismatch");
    let ((r0, r1), (c0, c1)) = spec.ranges(idx);
    t.block(r0, r1, c0, c1)
}

/// Copy block `idx` of `t` into a preallocated block tensor — the
/// zero-alloc sibling of [`shard`] (the optimizer's steady-state arena
/// path reuses one block tensor per slot across steps).
pub fn shard_into(t: &Tensor, spec: &ShardSpec, idx: usize, out: &mut Tensor) {
    assert_eq!((t.m(), t.n()), (spec.m, spec.n), "spec/tensor mismatch");
    let ((r0, r1), (c0, c1)) = spec.ranges(idx);
    assert_eq!((out.m(), out.n()), (r1 - r0, c1 - c0), "shard_into shape");
    let n = t.n();
    let w = c1 - c0;
    let src = t.data();
    let dst = out.data_mut();
    for (bi, i) in (r0..r1).enumerate() {
        dst[bi * w..(bi + 1) * w]
            .copy_from_slice(&src[i * n + c0..i * n + c1]);
    }
}

/// Extract all blocks in block-id order (what an all-gather materializes).
pub fn shard_all(t: &Tensor, spec: &ShardSpec) -> Vec<Tensor> {
    (0..spec.num_blocks()).map(|i| shard(t, spec, i)).collect()
}

/// Reassemble the full matrix from blocks (the scatter inverse).
pub fn unshard(blocks: &[Tensor], spec: &ShardSpec) -> Tensor {
    let mut out = Tensor::zeros(&[spec.m, spec.n]);
    unshard_into(blocks, spec, &mut out);
    out
}

/// [`unshard`] into a preallocated full matrix (zero-alloc sibling).
pub fn unshard_into(blocks: &[Tensor], spec: &ShardSpec, out: &mut Tensor) {
    assert_eq!(blocks.len(), spec.num_blocks());
    assert_eq!((out.m(), out.n()), (spec.m, spec.n), "unshard_into shape");
    for (idx, b) in blocks.iter().enumerate() {
        let ((r0, _), (c0, _)) = spec.ranges(idx);
        out.set_block(r0, c0, b);
    }
}

/// Assemble `out` from blocks produced by `block_of` (block id → tensor):
/// the gather-free sibling of [`unshard_into`] for callers whose blocks
/// live in non-contiguous storage — the phased coordinator's leader phase
/// reads momentum/update blocks straight out of per-rank arenas without
/// collecting them into a slice first (zero allocations).
pub fn unshard_from<'a>(
    spec: &ShardSpec,
    out: &mut Tensor,
    block_of: impl Fn(usize) -> &'a Tensor,
) {
    assert_eq!((out.m(), out.n()), (spec.m, spec.n), "unshard_from shape");
    for idx in 0..spec.num_blocks() {
        let b = block_of(idx);
        let ((r0, r1), (c0, c1)) = spec.ranges(idx);
        assert_eq!((b.m(), b.n()), (r1 - r0, c1 - c0), "unshard_from block");
        out.set_block(r0, c0, b);
    }
}

// -- ZeRO-1 row slices -------------------------------------------------------
//
// Optimizer-state sharding partitions a matrix by *rows of the full
// matrix*, independently of the TP block layout: dp rank r owns rows
// `shard_range(m, dp, r)` of every momentum matrix. Row slices of a
// row-major tensor are contiguous, so every slice op below is a straight
// memcpy and the reduce-scatter/all-gather collectives built on them touch
// each element exactly once. When `dp > m`, trailing ranks own zero rows —
// an empty slice is a valid (0 x n) tensor that still participates in the
// collective rendezvous but moves no payload.

/// Rows `[start, end)` of the ZeRO-1 slice dp rank `r` owns in an
/// `m`-row matrix (balanced partition, same as [`shard_range`]).
pub fn row_slice_range(m: usize, dp: usize, r: usize) -> (usize, usize) {
    shard_range(m, dp, r)
}

/// Allocate dp rank `r`'s (possibly empty) momentum row-slice buffer for
/// an `m x n` matrix.
pub fn row_slice_zeros(m: usize, n: usize, dp: usize, r: usize) -> Tensor {
    let (r0, r1) = row_slice_range(m, dp, r);
    Tensor::zeros(&[r1 - r0, n])
}

/// Copy dp rank `r`'s row slice of `t` into a preallocated slice tensor
/// (zero-alloc; one contiguous memcpy).
pub fn row_slice_into(t: &Tensor, dp: usize, r: usize, out: &mut Tensor) {
    let (r0, r1) = row_slice_range(t.m(), dp, r);
    let n = t.n();
    assert_eq!((out.m(), out.n()), (r1 - r0, n), "row_slice_into shape");
    out.data_mut().copy_from_slice(&t.data()[r0 * n..r1 * n]);
}

/// Write dp rank `r`'s row slice back into the full matrix in place.
pub fn write_row_slice(t: &mut Tensor, dp: usize, r: usize, slice: &Tensor) {
    let (r0, r1) = row_slice_range(t.m(), dp, r);
    let n = t.n();
    assert_eq!(
        (slice.m(), slice.n()),
        (r1 - r0, n),
        "write_row_slice shape"
    );
    t.data_mut()[r0 * n..r1 * n].copy_from_slice(slice.data());
}

/// Write one block back into the full matrix in place.
pub fn write_shard(t: &mut Tensor, spec: &ShardSpec, idx: usize, block: &Tensor) {
    let ((r0, r1), (c0, c1)) = spec.ranges(idx);
    assert_eq!((block.m(), block.n()), (r1 - r0, c1 - c0));
    t.set_block(r0, c0, block);
}

/// Row-slab-granular sibling of [`shard_into`]: copy only the
/// intersection of full-matrix rows `[gr0, gr1)` with block `idx` from
/// `t` into the matching rows of the preallocated block tensor. The
/// overlapped coordinator schedule calls this the moment a reduced
/// row slab lands so the shard load starts while later slabs are still
/// on the wire; iterating a row partition of the matrix performs the
/// exact memcpys of one whole-block [`shard_into`]. Returns the
/// block-local row range written, or `None` when the slab misses the
/// block entirely.
pub fn shard_rows_into(
    t: &Tensor,
    spec: &ShardSpec,
    idx: usize,
    gr0: usize,
    gr1: usize,
    out: &mut Tensor,
) -> Option<(usize, usize)> {
    assert_eq!((t.m(), t.n()), (spec.m, spec.n), "spec/tensor mismatch");
    assert!(gr0 <= gr1 && gr1 <= spec.m, "row slab out of range");
    let ((r0, r1), (c0, c1)) = spec.ranges(idx);
    assert_eq!(
        (out.m(), out.n()),
        (r1 - r0, c1 - c0),
        "shard_rows_into shape"
    );
    let lo = gr0.max(r0);
    let hi = gr1.min(r1);
    if lo >= hi {
        return None;
    }
    let n = t.n();
    let w = c1 - c0;
    let src = t.data();
    let dst = out.data_mut();
    for i in lo..hi {
        let bi = i - r0;
        dst[bi * w..(bi + 1) * w]
            .copy_from_slice(&src[i * n + c0..i * n + c1]);
    }
    Some((lo - r0, hi - r0))
}

/// Slice-resident sibling of [`shard_rows_into`]: copy the intersection
/// of a row slice (full-matrix rows `[sr0, sr0 + slice.m())`, all `n`
/// columns — e.g. a ZeRO-2 reduce-scattered accumulator) with block
/// `idx` of the full matrix into the matching rows of the preallocated
/// block tensor. The ZeRO-2 data path assembles TP blocks directly from
/// the DP ranks' slice arenas — no full synced matrix ever exists — and
/// iterating a row partition's slices performs the exact memcpys of one
/// whole-block [`shard_into`] on the assembled matrix (bit-identity).
/// Returns the block-local row range written, or `None` when the slice
/// misses the block entirely.
pub fn shard_rows_from_slice(
    slice: &Tensor,
    sr0: usize,
    spec: &ShardSpec,
    idx: usize,
    out: &mut Tensor,
) -> Option<(usize, usize)> {
    assert_eq!(slice.n(), spec.n, "slice/spec column mismatch");
    let (sr1, n) = (sr0 + slice.m(), spec.n);
    assert!(sr1 <= spec.m, "slice rows out of range");
    let ((r0, r1), (c0, c1)) = spec.ranges(idx);
    assert_eq!(
        (out.m(), out.n()),
        (r1 - r0, c1 - c0),
        "shard_rows_from_slice shape"
    );
    let lo = sr0.max(r0);
    let hi = sr1.min(r1);
    if lo >= hi {
        return None;
    }
    let w = c1 - c0;
    let src = slice.data();
    let dst = out.data_mut();
    for i in lo..hi {
        let si = i - sr0;
        let bi = i - r0;
        dst[bi * w..(bi + 1) * w]
            .copy_from_slice(&src[si * n + c0..si * n + c1]);
    }
    Some((lo - r0, hi - r0))
}

// -- GradSource --------------------------------------------------------------

/// The trainer-to-coordinator gradient seam. A `GradSource` is a view
/// over the step's gradients that the optimizer consumes either as full
/// tensors (replicated/ZeRO-1) or as row-slab views (the ZeRO-2 data
/// path, where a DP rank's collectives only ever read its `1/dp`
/// row-slice of each matrix). Borrowed, never owning: building one
/// allocates nothing, so the trainer's hot loop stays zero-alloc.
pub struct GradSource<'a> {
    grads: &'a [Tensor],
}

impl<'a> GradSource<'a> {
    pub fn new(grads: &'a [Tensor]) -> GradSource<'a> {
        GradSource { grads }
    }

    /// The underlying gradient tensors, for optimizers that consume
    /// whole matrices.
    pub fn tensors(&self) -> &'a [Tensor] {
        self.grads
    }

    pub fn len(&self) -> usize {
        self.grads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grads.is_empty()
    }

    /// Full gradient tensor for param `i`.
    pub fn full(&self, i: usize) -> &'a Tensor {
        &self.grads[i]
    }

    /// Rows `[r0, r1)` of param `i` as a contiguous element slice (row
    /// slices of a row-major tensor are contiguous) — what a ZeRO-2
    /// rank's reduce-scatter deposit reads.
    pub fn rows(&self, i: usize, r0: usize, r1: usize) -> &'a [f32] {
        let t = &self.grads[i];
        let n = t.n();
        assert!(r0 <= r1 && r1 <= t.m(), "GradSource::rows out of range");
        &t.data()[r0 * n..r1 * n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::prop;
    use crate::utils::rng::Rng;

    #[test]
    fn ranges_cover_dim() {
        for (dim, n) in [(10, 3), (8, 4), (7, 7), (5, 1), (9, 2)] {
            let mut covered = 0;
            for i in 0..n {
                let (s, e) = shard_range(dim, n, i);
                assert_eq!(s, covered, "gap at shard {i}");
                covered = e;
            }
            assert_eq!(covered, dim);
        }
    }

    #[test]
    fn balanced_ranges() {
        // sizes differ by at most 1
        for (dim, n) in [(10, 3), (100, 7), (16, 5)] {
            let sizes: Vec<usize> = (0..n)
                .map(|i| {
                    let (s, e) = shard_range(dim, n, i);
                    e - s
                })
                .collect();
            let mx = sizes.iter().max().unwrap();
            let mn = sizes.iter().min().unwrap();
            assert!(mx - mn <= 1, "{sizes:?}");
        }
    }

    #[test]
    fn shard_unshard_roundtrip_property() {
        prop::check("shard-roundtrip", 20, |rng| {
            let m = rng.gen_range(1, 40);
            let n = rng.gen_range(1, 40);
            let layouts = [
                Layout::Replicated,
                Layout::TpColumn,
                Layout::TpRow,
                Layout::Fsdp2Dim0,
                Layout::ZeroLayer,
            ];
            let layout = layouts[rng.gen_range(0, layouts.len())];
            let tp = rng.gen_range(1, 9);
            let t = Tensor::randn(&[m, n], 1.0, rng);
            let spec = ShardSpec::new(layout, tp, m, n);
            let blocks = shard_all(&t, &spec);
            let back = unshard(&blocks, &spec);
            if back != t {
                return Err(format!(
                    "roundtrip failed for {layout:?} tp={tp} {m}x{n}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn grid_roundtrip() {
        let mut rng = Rng::new(5);
        let t = Tensor::randn(&[12, 18], 1.0, &mut rng);
        let spec =
            ShardSpec::new(Layout::TpGrid { rows: 2, cols: 3 }, 6, 12, 18);
        assert_eq!(spec.num_blocks(), 6);
        assert_eq!(spec.block_shape(0), (6, 6));
        let blocks = shard_all(&t, &spec);
        assert_eq!(unshard(&blocks, &spec), t);
    }

    #[test]
    fn unshard_from_matches_unshard() {
        let mut rng = Rng::new(9);
        let t = Tensor::randn(&[10, 14], 1.0, &mut rng);
        let spec =
            ShardSpec::new(Layout::TpGrid { rows: 2, cols: 3 }, 6, 10, 14);
        let blocks = shard_all(&t, &spec);
        let mut out = Tensor::zeros(&[10, 14]);
        unshard_from(&spec, &mut out, |b| &blocks[b]);
        assert_eq!(out, t);
    }

    #[test]
    fn write_shard_updates_in_place() {
        let mut t = Tensor::zeros(&[4, 8]);
        let spec = ShardSpec::new(Layout::TpColumn, 4, 4, 8);
        let mut b = Tensor::zeros(&[4, 2]);
        b.data_mut().fill(3.0);
        write_shard(&mut t, &spec, 2, &b);
        assert_eq!(t.at(0, 4), 3.0);
        assert_eq!(t.at(3, 5), 3.0);
        assert_eq!(t.at(0, 3), 0.0);
        assert_eq!(t.at(0, 6), 0.0);
    }

    #[test]
    fn row_slices_tile_the_matrix() {
        // Slice out + write back must reconstruct the matrix exactly, for
        // balanced, ragged and clamped (dp > m) partitions alike.
        let mut rng = Rng::new(17);
        for (m, n, dp) in [(8, 6, 2), (9, 4, 4), (2, 9, 4), (5, 3, 1)] {
            let t = Tensor::randn(&[m, n], 1.0, &mut rng);
            let mut back = Tensor::zeros(&[m, n]);
            let mut covered = 0;
            for r in 0..dp {
                let (r0, r1) = row_slice_range(m, dp, r);
                assert_eq!(r0, covered, "gap before rank {r}");
                covered = r1;
                let mut slice = row_slice_zeros(m, n, dp, r);
                row_slice_into(&t, dp, r, &mut slice);
                write_row_slice(&mut back, dp, r, &slice);
            }
            assert_eq!(covered, m);
            assert_eq!(back, t, "({m},{n},dp={dp}) roundtrip");
        }
        // dp > m: trailing ranks own empty slices.
        let empty = row_slice_zeros(2, 9, 4, 3);
        assert_eq!((empty.m(), empty.n()), (0, 9));
        assert_eq!(empty.numel(), 0);
    }

    #[test]
    fn block_bytes() {
        let spec = ShardSpec::new(Layout::TpColumn, 4, 8, 16);
        assert_eq!(spec.block_bytes(0), 8 * 4 * 4);
    }

    #[test]
    fn shard_rows_tiles_shard_into_exactly() {
        // Iterating shard_rows_into over any row partition of the full
        // matrix must perform the exact copies of one shard_into call,
        // for every block of row/column/grid layouts.
        let mut rng = Rng::new(23);
        for (layout, tp) in [
            (Layout::TpRow, 4),
            (Layout::TpColumn, 3),
            (Layout::TpGrid { rows: 2, cols: 2 }, 4),
        ] {
            let (m, n) = (10, 6);
            let t = Tensor::randn(&[m, n], 1.0, &mut rng);
            let spec = ShardSpec::new(layout, tp, m, n);
            for idx in 0..spec.num_blocks() {
                let (bm, bn) = spec.block_shape(idx);
                let mut whole = Tensor::zeros(&[bm, bn]);
                shard_into(&t, &spec, idx, &mut whole);
                for n_slabs in [1, 3, m] {
                    let mut tiled = Tensor::zeros(&[bm, bn]);
                    let mut covered = 0;
                    for j in 0..n_slabs {
                        let (g0, g1) = shard_range(m, n_slabs, j);
                        if let Some((b0, b1)) =
                            shard_rows_into(&t, &spec, idx, g0, g1, &mut tiled)
                        {
                            assert!(b0 < b1 && b1 <= bm);
                            covered += b1 - b0;
                        }
                    }
                    assert_eq!(covered, bm, "{layout:?} block {idx} rows");
                    assert_eq!(tiled, whole, "{layout:?} block {idx}");
                }
            }
        }
        // A slab that misses the block entirely reports None and writes
        // nothing.
        let t = Tensor::zeros(&[8, 4]);
        let spec = ShardSpec::new(Layout::TpRow, 2, 8, 4);
        let mut b = Tensor::zeros(&[4, 4]);
        assert_eq!(shard_rows_into(&t, &spec, 1, 0, 4, &mut b), None);
        assert_eq!(shard_rows_into(&t, &spec, 0, 4, 8, &mut b), None);
    }

    #[test]
    fn shard_rows_from_slice_matches_assembled_matrix() {
        // Assembling a block from a DP row-slice partition must equal
        // shard_into on the full matrix, for every block and every dp
        // degree (including clamped dp > m with empty slices).
        let mut rng = Rng::new(31);
        for (layout, tp) in [
            (Layout::TpRow, 4),
            (Layout::TpColumn, 3),
            (Layout::TpGrid { rows: 2, cols: 2 }, 4),
        ] {
            let (m, n) = (9, 6);
            let t = Tensor::randn(&[m, n], 1.0, &mut rng);
            let spec = ShardSpec::new(layout, tp, m, n);
            for dp in [1, 2, 4, 12] {
                for idx in 0..spec.num_blocks() {
                    let (bm, bn) = spec.block_shape(idx);
                    let mut whole = Tensor::zeros(&[bm, bn]);
                    shard_into(&t, &spec, idx, &mut whole);
                    let mut tiled = Tensor::zeros(&[bm, bn]);
                    let mut covered = 0;
                    for r in 0..dp {
                        let (s0, _) = row_slice_range(m, dp, r);
                        let mut slice = row_slice_zeros(m, n, dp, r);
                        row_slice_into(&t, dp, r, &mut slice);
                        if let Some((b0, b1)) = shard_rows_from_slice(
                            &slice, s0, &spec, idx, &mut tiled,
                        ) {
                            assert!(b0 < b1 && b1 <= bm);
                            covered += b1 - b0;
                        }
                    }
                    assert_eq!(covered, bm, "{layout:?} dp={dp} blk {idx}");
                    assert_eq!(tiled, whole, "{layout:?} dp={dp} blk {idx}");
                }
            }
        }
    }

    #[test]
    fn grad_source_views_match_tensors() {
        let mut rng = Rng::new(41);
        let grads =
            vec![Tensor::randn(&[6, 4], 1.0, &mut rng), Tensor::zeros(&[3])];
        let src = GradSource::new(&grads);
        assert_eq!(src.len(), 2);
        assert!(!src.is_empty());
        assert_eq!(src.full(0), &grads[0]);
        assert_eq!(src.tensors().len(), 2);
        // Row views are exactly the matching contiguous element range.
        let (r0, r1) = row_slice_range(6, 2, 1);
        assert_eq!(src.rows(0, r0, r1), &grads[0].data()[r0 * 4..r1 * 4]);
        assert_eq!(src.rows(0, 0, 6), grads[0].data());
        assert!(src.rows(0, 2, 2).is_empty());
    }
}
