//! Statistical bench harness (criterion is unavailable offline): warmup +
//! N timed samples, mean/p50/p95 reporting, and shared result-dir helpers
//! used by every `rust/benches/*.rs` (all `harness = false`).

use std::path::PathBuf;
use std::time::Instant;

use crate::utils::json::Json;
use crate::utils::stats::Stats;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {:>10.6}s  p50 {:>10.6}s  p95 {:>10.6}s  (n={})",
            self.name, self.mean_s, self.p50_s, self.p95_s, self.samples
        )
    }

    /// Machine-readable record for the perf-trajectory files
    /// (`results/BENCH_*.json`). `kind`/`shape` identify the kernel;
    /// `flops == 0` means "no GFLOP/s figure for this entry";
    /// `speedup_vs_ref == 0` likewise.
    pub fn to_json(
        &self,
        kind: &str,
        shape: &str,
        flops: f64,
        speedup_vs_ref: f64,
    ) -> Json {
        let mut kv = vec![
            ("name", Json::str(&self.name)),
            ("kind", Json::str(kind)),
            ("shape", Json::str(shape)),
            ("mean_s", Json::num(self.mean_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("samples", Json::num(self.samples as f64)),
        ];
        if flops > 0.0 {
            kv.push(("gflops", Json::num(flops / self.mean_s / 1e9)));
        }
        if speedup_vs_ref > 0.0 {
            kv.push(("speedup_vs_naive", Json::num(speedup_vs_ref)));
        }
        Json::obj(kv)
    }
}

/// Write a perf-results JSON artifact under `results/` and report its
/// path. Entries are wrapped as `{"bench": name, "entries": [...]}` so
/// the perf trajectory across PRs is diffable per kernel.
pub fn save_bench_json(name: &str, entries: &[Json]) -> PathBuf {
    let doc = Json::obj(vec![
        ("bench", Json::str(name)),
        ("entries", Json::Arr(entries.to_vec())),
    ]);
    let path = results_dir().join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
        eprintln!("warn: could not write {}: {e}", path.display());
    } else {
        println!("  -> {}", path.display());
    }
    path
}

/// Time `f` with `warmup` untimed and `iters` timed runs.
pub fn time_it<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        mean_s: stats.mean(),
        p50_s: stats.percentile(50.0),
        p95_s: stats.percentile(95.0),
        samples: stats.len(),
    };
    println!("{}", r.report());
    r
}

/// Directory where benches drop CSV/JSON artifacts.
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Banner helper so bench output maps 1:1 to the paper artifact.
pub fn banner(what: &str) {
    println!("\n================================================================");
    println!("  {what}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_counted() {
        let r = time_it("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(r.samples, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s);
    }

    #[test]
    fn results_dir_exists() {
        assert!(results_dir().is_dir());
    }
}
