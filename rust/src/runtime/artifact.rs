//! artifacts/manifest.json parsing — the contract between `aot.py` (which
//! owns parameter ordering and shapes) and the rust side.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::optim::{ParamKind, ParamMeta};
use crate::utils::json::Json;

/// One parameter entry (ordered exactly as the artifact's arguments).
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: ParamKind,
    pub init_scale: f64,
}

/// One model config's artifact set.
#[derive(Debug, Clone)]
pub struct ConfigEntry {
    pub name: String,
    pub n_params: usize,
    pub params: Vec<ParamEntry>,
    pub train_hlo: String,
    pub eval_hlo: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
}

impl ConfigEntry {
    pub fn metas(&self) -> Vec<ParamMeta> {
        self.params
            .iter()
            .map(|p| ParamMeta::new(&p.name, &p.shape, p.kind))
            .collect()
    }

    /// Tokens per train step (batch x (seq+1) fed, batch x seq predicted).
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq_len
    }
}

/// A lowered Newton–Schulz kernel artifact.
#[derive(Debug, Clone)]
pub struct NsKernelEntry {
    pub shape: (usize, usize),
    pub steps: usize,
    pub hlo: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub configs: Vec<ConfigEntry>,
    pub ns_kernels: Vec<NsKernelEntry>,
    pub ns_steps: usize,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        if root.req("format")?.as_str()? != "hlo-text" {
            anyhow::bail!("unsupported artifact format");
        }
        let ns_steps = root.req("ns_steps")?.as_usize()?;
        let mut configs = Vec::new();
        for (name, entry) in root.req("configs")?.as_obj()? {
            let cfg = entry.req("config")?;
            let mut params = Vec::new();
            for p in entry.req("params")?.as_arr()? {
                params.push(ParamEntry {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                    kind: ParamKind::parse(p.req("kind")?.as_str()?)?,
                    init_scale: p.req("init_scale")?.as_f64()?,
                });
            }
            configs.push(ConfigEntry {
                name: name.clone(),
                n_params: entry.req("n_params")?.as_usize()?,
                params,
                train_hlo: entry.req("train_hlo")?.as_str()?.to_string(),
                eval_hlo: entry.req("eval_hlo")?.as_str()?.to_string(),
                vocab: cfg.req("vocab")?.as_usize()?,
                d_model: cfg.req("d_model")?.as_usize()?,
                n_layers: cfg.req("n_layers")?.as_usize()?,
                n_heads: cfg.req("n_heads")?.as_usize()?,
                n_kv_heads: cfg.req("n_kv_heads")?.as_usize()?,
                d_ff: cfg.req("d_ff")?.as_usize()?,
                seq_len: cfg.req("seq_len")?.as_usize()?,
                batch: cfg.req("batch")?.as_usize()?,
            });
        }
        let mut ns_kernels = Vec::new();
        for k in root.req("ns_kernels")?.as_arr()? {
            let dims = k.req("shape")?.as_arr()?;
            ns_kernels.push(NsKernelEntry {
                shape: (dims[0].as_usize()?, dims[1].as_usize()?),
                steps: k.req("steps")?.as_usize()?,
                hlo: k.req("hlo")?.as_str()?.to_string(),
            });
        }
        Ok(Manifest { configs, ns_kernels, ns_steps })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow!("no config '{name}' in manifest"))
    }

    pub fn ns_kernel(&self, m: usize, n: usize) -> Option<&NsKernelEntry> {
        self.ns_kernels.iter().find(|k| k.shape == (m, n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "ns_steps": 5,
      "configs": {
        "tiny": {
          "config": {"name":"tiny","vocab":256,"d_model":64,"n_layers":2,
                     "n_heads":4,"n_kv_heads":2,"d_ff":176,"seq_len":64,
                     "batch":4,"rope_theta":10000.0,"head_dim":16,"kv_dim":32},
          "n_params": 1000,
          "params": [
            {"name":"embed.weight","shape":[256,64],"kind":"embed","init_scale":0.02},
            {"name":"layers.00.attn.wq","shape":[64,64],"kind":"matrix","init_scale":0.02},
            {"name":"final_norm.gain","shape":[64],"kind":"vector","init_scale":1.0}
          ],
          "train_hlo": "train_tiny.hlo.txt",
          "eval_hlo": "eval_tiny.hlo.txt"
        }
      },
      "ns_kernels": [{"shape":[128,128],"steps":5,"hlo":"ns_128x128.hlo.txt"}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let cfg = m.config("tiny").unwrap();
        assert_eq!(cfg.params.len(), 3);
        assert_eq!(cfg.params[1].shape, vec![64, 64]);
        assert_eq!(cfg.params[1].kind, ParamKind::Matrix);
        assert_eq!(cfg.d_ff, 176);
        assert_eq!(cfg.tokens_per_step(), 4 * 64);
        assert!(m.ns_kernel(128, 128).is_some());
        assert!(m.ns_kernel(64, 64).is_none());
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format":"other"}"#).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Validate against the actual artifacts when present.
        for dir in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = std::path::Path::new(dir).join("manifest.json");
            if p.exists() {
                let m = Manifest::load(&p).unwrap();
                assert!(m.config("tiny").is_ok());
                assert!(m.config("bench").is_ok());
                assert!(m.config("e2e").is_ok());
                assert!(!m.ns_kernels.is_empty());
                // Param order must be sorted by name (aot.py contract).
                let cfg = m.config("tiny").unwrap();
                let names: Vec<_> =
                    cfg.params.iter().map(|p| p.name.clone()).collect();
                let mut sorted = names.clone();
                sorted.sort();
                assert_eq!(names, sorted);
                return;
            }
        }
    }
}
