//! Ready-count task-graph executor on top of [`Pool`].
//!
//! The phased coordinator schedule (PR 3) ran each step as whole-phase
//! fan-outs with a full barrier between phases: the pool idled during
//! collectives and the transport idled during compute. `TaskDag` replaces
//! the barriers with dependency counts: a step is a graph of preallocated
//! task records, workers pop ready nodes and decrement successor counts,
//! and a node starts the moment its inputs exist — so a momentum
//! row-slab's update can run while later slabs are still on the wire.
//!
//! # Execution model
//!
//! Nodes are either **lane-pinned** or **shared**:
//!
//! - A *lane* is a totally-ordered node sequence executed by exactly one
//!   worker (worker `w` owns lane `w`). The coordinator pins collective
//!   rounds to lanes — one lane per DP rank — so every lane enters the
//!   same transport rounds in the same global order, preserving the
//!   fixed rank/slab deposit order the bit-identity contract requires.
//!   A lane node may block inside a transport rendezvous; its lane
//!   worker is dedicated, so the rendezvous always completes (all lanes
//!   are live concurrently under one `run_concurrent`).
//! - A *shared* node (compute: shard loads, momentum updates, block NS,
//!   reassembly copies) is pushed to a common ready queue when its
//!   dependency count hits zero and may be claimed by any worker —
//!   including a lane worker whose next pinned node is not ready yet, so
//!   a stalled lane helps with compute instead of spinning.
//!
//! # Failure semantics (PR-6 poisonable-barrier contract)
//!
//! Every node body runs under `catch_unwind`; the caller's `on_fail`
//! hook observes each failure and grades it:
//!
//! - [`Severity::Hard`] (every panic, and any `Err` the hook grades so)
//!   poisons the graph: the poison flag stops every worker from
//!   claiming further nodes, and the hook typically poisons the
//!   transport too, releasing lanes parked inside a collective with
//!   `Poisoned` instead of deadlocking — those secondary failures
//!   report through `on_fail` as well, and the caller's error slot
//!   keeps the first concrete cause.
//! - [`Severity::Soft`] records the failure but keeps the graph
//!   draining: the failed node's transitive dependents are *tainted*
//!   (skipped, never executed — poison propagation along dep edges)
//!   while every other node still runs. The coordinator grades NS
//!   divergence soft so the DP collective lanes finish their rounds
//!   and the synced accumulators stay complete for the
//!   `escalate-full-orth` retry. Taint flows only through declared
//!   `dep` edges, so pinning a dependent of a fallibly-soft node to a
//!   lane (whose peers rendezvous by round count) is the caller's
//!   responsibility to avoid.
//!
//! `run` always joins every worker before returning, which is the
//! quiescence a subsequent transport `heal` requires.
//!
//! # Zero steady-state allocations
//!
//! All node storage lives in grow-only buffers owned by the `TaskDag`:
//! `begin` resets lengths without dropping capacity (per-node successor
//! lists and per-lane sequences are slot-reused, never cleared away), so
//! rebuilding the same step graph allocates nothing once every buffer
//! has reached its high-water size — proved end to end by
//! `tests/ns_zero_alloc.rs` through warm overlapped `DistMuon` steps.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::runtime::pool::{Pool, WorkerArena};

/// Sentinel lane id for shared (work-stealable) nodes.
const NO_LANE: u32 = u32::MAX;

/// Lane that simulated rank `rank` rides when a `world`-rank schedule is
/// folded onto `n_lanes < world` lanes: round-robin, so lane `L` carries
/// ranks `{L, L + n_lanes, L + 2·n_lanes, …}`. Round-robin (rather than
/// contiguous chunks) keeps rank 0 on lane 0 for every lane count, which
/// the coordinator's "lane 0 charges/times the collective" convention
/// relies on.
pub fn lane_of_rank(rank: usize, n_lanes: usize) -> usize {
    debug_assert!(n_lanes >= 1);
    rank % n_lanes
}

/// The full round-robin assignment: `lane_ranks(world, n_lanes)[L]` is
/// the ordered rank list lane `L` represents. With `n_lanes == world`
/// every lane carries exactly its own rank — the degenerate case in
/// which a folded schedule is byte-for-byte the unfolded one.
pub fn lane_ranks(world: usize, n_lanes: usize) -> Vec<Vec<usize>> {
    debug_assert!(n_lanes >= 1 && n_lanes <= world);
    (0..n_lanes)
        .map(|l| (l..world).step_by(n_lanes).collect())
        .collect()
}

/// How a node failed; handed to the `on_fail` hook so the caller can map
/// the node kind to a structured error (e.g. `StepError::RankPanicked`
/// with the schedule phase the node belongs to).
pub enum DagFailure<K, E> {
    /// The node body returned `Err`.
    Err { kind: K, err: E },
    /// The node body panicked (caught; the panic payload is dropped, as
    /// in the pooled phase fan-outs).
    Panic { kind: K },
}

/// The `on_fail` hook's verdict on a failed node (see module docs).
/// Panicked nodes always poison the graph — their hook verdict is
/// ignored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Poison the whole graph: no further nodes run.
    Hard,
    /// Skip the failed node's transitive dependents; drain the rest.
    Soft,
}

/// Shared ready queue: a grow-only ring consumed front to back. One run
/// pushes at most `n_nodes` ids, so `buf` never exceeds node-count
/// capacity and a warm run never reallocates it.
struct Ready {
    buf: Vec<u32>,
    head: usize,
}

/// A reusable dependency-graph of `K`-tagged task records (see module
/// docs). `K` is a small `Copy` tag the caller's executor closure
/// matches on — the dag stores no closures, which is what keeps rebuilds
/// allocation-free.
pub struct TaskDag<K: Copy> {
    kinds: Vec<K>,
    lane_of: Vec<u32>,
    /// Static dependency count per node (set at build).
    preds: Vec<u32>,
    /// Successor lists, slot-reused across rebuilds.
    succ: Vec<Vec<u32>>,
    /// Runtime countdown of unmet dependencies.
    pending: Vec<AtomicU32>,
    /// Poison-propagation marks: a tainted node is skipped (its own
    /// taint spreads to its successors) instead of executed.
    tainted: Vec<AtomicBool>,
    /// Per-lane node sequences (execution order).
    lanes: Vec<Vec<u32>>,
    n_nodes: usize,
    n_lanes: usize,
    ready: Mutex<Ready>,
    done: AtomicUsize,
    poisoned: AtomicBool,
}

impl<K: Copy + Send + Sync> TaskDag<K> {
    pub fn new() -> TaskDag<K> {
        TaskDag {
            kinds: Vec::new(),
            lane_of: Vec::new(),
            preds: Vec::new(),
            succ: Vec::new(),
            pending: Vec::new(),
            tainted: Vec::new(),
            lanes: Vec::new(),
            n_nodes: 0,
            n_lanes: 0,
            ready: Mutex::new(Ready { buf: Vec::new(), head: 0 }),
            done: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Start a new graph with `n_lanes` pinned lanes. Keeps every
    /// buffer's capacity (slot reuse), so rebuilding a previously-built
    /// shape allocates nothing.
    pub fn begin(&mut self, n_lanes: usize) {
        self.n_nodes = 0;
        self.n_lanes = n_lanes;
        while self.lanes.len() < n_lanes {
            self.lanes.push(Vec::new());
        }
        for lane in &mut self.lanes {
            lane.clear();
        }
    }

    /// Add a node. `lane: Some(l)` pins it to lane `l` (appended to that
    /// lane's execution order); `None` makes it shared. Returns the node
    /// id for wiring dependencies.
    pub fn add(&mut self, kind: K, lane: Option<usize>) -> u32 {
        let id = self.n_nodes;
        if id < self.kinds.len() {
            self.kinds[id] = kind;
            self.lane_of[id] = NO_LANE;
            self.preds[id] = 0;
            self.succ[id].clear();
        } else {
            self.kinds.push(kind);
            self.lane_of.push(NO_LANE);
            self.preds.push(0);
            self.succ.push(Vec::new());
            self.pending.push(AtomicU32::new(0));
            self.tainted.push(AtomicBool::new(false));
        }
        if let Some(l) = lane {
            debug_assert!(l < self.n_lanes);
            self.lane_of[id] = l as u32;
            self.lanes[l].push(id as u32);
        }
        self.n_nodes += 1;
        id as u32
    }

    /// Declare that `before` must complete before `after` starts.
    /// (Lane order is implicit within a lane; only cross-producer edges
    /// need declaring.)
    pub fn dep(&mut self, before: u32, after: u32) {
        debug_assert!((before as usize) < self.n_nodes);
        debug_assert!((after as usize) < self.n_nodes);
        debug_assert_ne!(before, after);
        self.succ[before as usize].push(after);
        self.preds[after as usize] += 1;
    }

    pub fn node_count(&self) -> usize {
        self.n_nodes
    }

    /// Execute the graph on `workers` concurrent pool workers (must be
    /// >= the lane count; lanes are pinned to workers `0..n_lanes`).
    /// Returns after every worker joined — either all nodes completed,
    /// were skipped downstream of a soft failure, or the graph was
    /// hard-poisoned. `exec` runs each node; `on_fail` observes every
    /// failing node (first concrete failure plus any secondary
    /// `Poisoned` releases) and grades `Err`s [`Severity::Hard`] or
    /// [`Severity::Soft`]; panics are always hard.
    pub fn run<E, X, P>(&mut self, workers: usize, exec: X, on_fail: P)
    where
        E: Send,
        X: Fn(K, &mut WorkerArena) -> Result<(), E> + Sync,
        P: Fn(DagFailure<K, E>) -> Severity + Sync,
    {
        assert!(
            workers >= self.n_lanes,
            "dag: {} workers < {} lanes",
            workers,
            self.n_lanes
        );
        // Seal: arm the countdowns, queue initially-ready shared nodes.
        self.done.store(0, Ordering::Relaxed);
        self.poisoned.store(false, Ordering::Relaxed);
        {
            let mut q = lock(&self.ready);
            q.buf.clear();
            q.head = 0;
            for id in 0..self.n_nodes {
                self.pending[id].store(self.preds[id], Ordering::Relaxed);
                self.tainted[id].store(false, Ordering::Relaxed);
                if self.preds[id] == 0 && self.lane_of[id] == NO_LANE {
                    q.buf.push(id as u32);
                }
            }
        }
        let this = &*self;
        Pool::global().run_concurrent(workers.max(1), |w, arena| {
            this.worker(w, arena, &exec, &on_fail)
        });
    }

    fn worker<E, X, P>(
        &self,
        w: usize,
        arena: &mut WorkerArena,
        exec: &X,
        on_fail: &P,
    ) where
        E: Send,
        X: Fn(K, &mut WorkerArena) -> Result<(), E> + Sync,
        P: Fn(DagFailure<K, E>) -> Severity + Sync,
    {
        let lane: Option<&[u32]> =
            (w < self.n_lanes).then(|| self.lanes[w].as_slice());
        let mut lane_pos = 0usize;
        loop {
            if self.poisoned.load(Ordering::Acquire) {
                return;
            }
            if self.done.load(Ordering::Acquire) == self.n_nodes {
                return;
            }
            // Own lane first: pinned nodes run in sequence order.
            if let Some(lane) = lane {
                if let Some(&id) = lane.get(lane_pos) {
                    if self.pending[id as usize].load(Ordering::Acquire)
                        == 0
                    {
                        self.run_node(id, arena, exec, on_fail);
                        lane_pos += 1;
                        continue;
                    }
                }
            }
            // Otherwise steal a shared ready node (a stalled lane helps
            // with compute instead of spinning).
            if let Some(id) = self.pop() {
                self.run_node(id, arena, exec, on_fail);
                continue;
            }
            std::thread::yield_now();
        }
    }

    fn run_node<E, X, P>(
        &self,
        id: u32,
        arena: &mut WorkerArena,
        exec: &X,
        on_fail: &P,
    ) where
        E: Send,
        X: Fn(K, &mut WorkerArena) -> Result<(), E> + Sync,
        P: Fn(DagFailure<K, E>) -> Severity + Sync,
    {
        // A node downstream of a soft failure is skipped, and its taint
        // spreads to its own successors. The claim path observed
        // `pending == 0`, which synchronizes with the predecessor's
        // decrement — so the taint mark (stored before that decrement)
        // is visible here.
        if self.tainted[id as usize].load(Ordering::Acquire) {
            self.skip(id);
            return;
        }
        let kind = self.kinds[id as usize];
        match catch_unwind(AssertUnwindSafe(|| exec(kind, arena))) {
            Ok(Ok(())) => self.complete(id),
            Ok(Err(err)) => {
                match on_fail(DagFailure::Err { kind, err }) {
                    Severity::Hard => {
                        // The hook already ran (typically poisoning the
                        // transport to release parked lanes); now stop
                        // every worker from claiming new nodes.
                        self.poisoned.store(true, Ordering::Release);
                    }
                    Severity::Soft => self.skip(id),
                }
            }
            Err(_) => {
                // Panics are always hard: the failed node may have left
                // shared state (an arena mid-iteration) inconsistent.
                let _ = on_fail(DagFailure::Panic { kind });
                self.poisoned.store(true, Ordering::Release);
            }
        }
    }

    fn complete(&self, id: u32) {
        for &s in &self.succ[id as usize] {
            let left =
                self.pending[s as usize].fetch_sub(1, Ordering::AcqRel);
            debug_assert!(left >= 1, "dag: successor count underflow");
            if left == 1 && self.lane_of[s as usize] == NO_LANE {
                lock(&self.ready).buf.push(s);
            }
        }
        self.done.fetch_add(1, Ordering::AcqRel);
    }

    /// Account a failed-soft or tainted node as done without running it,
    /// spreading its taint to every successor. Tainted shared nodes
    /// still flow through the ready queue so a worker claims them and
    /// propagates further; tainted lane nodes are skipped in lane order.
    fn skip(&self, id: u32) {
        for &s in &self.succ[id as usize] {
            // Store the mark BEFORE the countdown: the claimer that
            // observes pending == 0 acquires the final decrement and
            // therefore sees the mark.
            self.tainted[s as usize].store(true, Ordering::Release);
            let left =
                self.pending[s as usize].fetch_sub(1, Ordering::AcqRel);
            debug_assert!(left >= 1, "dag: successor count underflow");
            if left == 1 && self.lane_of[s as usize] == NO_LANE {
                lock(&self.ready).buf.push(s);
            }
        }
        self.done.fetch_add(1, Ordering::AcqRel);
    }

    fn pop(&self) -> Option<u32> {
        let mut q = lock(&self.ready);
        if q.head < q.buf.len() {
            let id = q.buf[q.head];
            q.head += 1;
            Some(id)
        } else {
            None
        }
    }
}

/// Mutex guard that survives a poisoned std mutex: a worker panic is
/// already reported through the dag's own poison flag, and the queue
/// state stays consistent (push/pop are single-field updates).
fn lock(m: &Mutex<Ready>) -> std::sync::MutexGuard<'_, Ready> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// A -> B -> C chain must execute in order regardless of worker
    /// count; completion order is observed via an append log.
    #[test]
    fn chain_respects_dependencies() {
        let mut dag: TaskDag<usize> = TaskDag::new();
        dag.begin(0);
        let a = dag.add(0, None);
        let b = dag.add(1, None);
        let c = dag.add(2, None);
        dag.dep(a, b);
        dag.dep(b, c);
        let log = Mutex::new(Vec::new());
        dag.run::<(), _, _>(
            4,
            |k, _| {
                log.lock().unwrap().push(k);
                Ok(())
            },
            |_| panic!("no failures expected"),
        );
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2]);
    }

    /// Diamond: s -> {l, r} -> j. The join must observe both branches.
    #[test]
    fn diamond_joins_both_branches() {
        for workers in [1, 2, 4] {
            let mut dag: TaskDag<u8> = TaskDag::new();
            dag.begin(0);
            let s = dag.add(0, None);
            let l = dag.add(1, None);
            let r = dag.add(2, None);
            let j = dag.add(3, None);
            dag.dep(s, l);
            dag.dep(s, r);
            dag.dep(l, j);
            dag.dep(r, j);
            let seen = AtomicU64::new(0);
            dag.run::<(), _, _>(
                workers,
                |k, _| {
                    if k == 3 {
                        assert_eq!(
                            seen.load(Ordering::SeqCst) & 0b110,
                            0b110,
                            "join ran before both branches"
                        );
                    }
                    seen.fetch_or(1 << k, Ordering::SeqCst);
                    Ok(())
                },
                |_| panic!("no failures expected"),
            );
            assert_eq!(seen.load(Ordering::SeqCst), 0b1111);
        }
    }

    /// Lane nodes run in pinned order on their lane even when shared
    /// nodes are interleaved and available.
    #[test]
    fn lanes_execute_in_order() {
        let mut dag: TaskDag<(usize, usize)> = TaskDag::new();
        dag.begin(2);
        for lane in 0..2 {
            for i in 0..5 {
                dag.add((lane, i), Some(lane));
            }
        }
        for i in 0..8 {
            dag.add((99, i), None);
        }
        let lane_log: [Mutex<Vec<usize>>; 2] =
            [Mutex::new(Vec::new()), Mutex::new(Vec::new())];
        dag.run::<(), _, _>(
            3,
            |(lane, i), _| {
                if lane < 2 {
                    lane_log[lane].lock().unwrap().push(i);
                }
                Ok(())
            },
            |_| panic!("no failures expected"),
        );
        for lane in 0..2 {
            assert_eq!(*lane_log[lane].lock().unwrap(), vec![0, 1, 2, 3, 4]);
        }
    }

    /// A panicking node poisons the graph: `run` still joins, the
    /// failure hook sees the panic, and dependents never execute.
    #[test]
    fn panic_poisons_and_skips_dependents() {
        let mut dag: TaskDag<u8> = TaskDag::new();
        dag.begin(0);
        let a = dag.add(0, None);
        let b = dag.add(1, None);
        dag.dep(a, b);
        let failures = AtomicU64::new(0);
        let ran_b = AtomicU64::new(0);
        dag.run::<(), _, _>(
            2,
            |k, _| {
                if k == 0 {
                    panic!("boom");
                }
                ran_b.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
            |f| {
                assert!(matches!(f, DagFailure::Panic { kind: 0 }));
                failures.fetch_add(1, Ordering::SeqCst);
                Severity::Hard
            },
        );
        assert_eq!(failures.load(Ordering::SeqCst), 1);
        assert_eq!(ran_b.load(Ordering::SeqCst), 0, "dependent ran");
    }

    /// A soft failure skips its transitive dependents but drains the
    /// rest of the graph — lanes included.
    #[test]
    fn soft_failure_skips_dependents_but_drains_the_rest() {
        let mut dag: TaskDag<u8> = TaskDag::new();
        dag.begin(1);
        // Lane 0: three pinned nodes that must all still run.
        for k in [10u8, 11, 12] {
            dag.add(k, Some(0));
        }
        // Shared: a(soft-fails) -> b -> c, plus independent d.
        let a = dag.add(0, None);
        let b = dag.add(1, None);
        let c = dag.add(2, None);
        dag.add(3, None); // d
        dag.dep(a, b);
        dag.dep(b, c);
        let ran = Mutex::new(Vec::new());
        let failures = AtomicU64::new(0);
        dag.run::<&str, _, _>(
            2,
            |k, _| {
                if k == 0 {
                    return Err("diverged");
                }
                ran.lock().unwrap().push(k);
                Ok(())
            },
            |f| {
                assert!(matches!(
                    f,
                    DagFailure::Err { kind: 0, err: "diverged" }
                ));
                failures.fetch_add(1, Ordering::SeqCst);
                Severity::Soft
            },
        );
        assert_eq!(failures.load(Ordering::SeqCst), 1);
        let mut ran = ran.lock().unwrap().clone();
        ran.sort_unstable();
        // b and c (dependents of the failed node) skipped; lane nodes
        // and the independent shared node all ran.
        assert_eq!(ran, vec![3, 10, 11, 12]);
    }

    /// An `Err` node reports through the hook with its error value.
    #[test]
    fn error_reports_kind_and_value() {
        let mut dag: TaskDag<u8> = TaskDag::new();
        dag.begin(0);
        dag.add(7, None);
        let failures = Mutex::new(Vec::new());
        dag.run::<i32, _, _>(
            1,
            |_, _| Err(41),
            |f| {
                match f {
                    DagFailure::Err { kind, err } => {
                        failures.lock().unwrap().push((kind, err))
                    }
                    DagFailure::Panic { .. } => panic!("not a panic"),
                }
                Severity::Hard
            },
        );
        assert_eq!(*failures.lock().unwrap(), vec![(7u8, 41i32)]);
    }

    /// Round-robin lane folding: every rank lands on exactly one lane,
    /// rank 0 always on lane 0, and `n_lanes == world` degenerates to
    /// the identity assignment.
    #[test]
    fn lane_folding_is_round_robin() {
        assert_eq!(lane_ranks(4, 4), vec![vec![0], vec![1], vec![2], vec![3]]);
        assert_eq!(lane_ranks(4, 2), vec![vec![0, 2], vec![1, 3]]);
        assert_eq!(lane_ranks(5, 2), vec![vec![0, 2, 4], vec![1, 3]]);
        assert_eq!(lane_ranks(3, 1), vec![vec![0, 1, 2]]);
        for world in 1..=8 {
            for n_lanes in 1..=world {
                let tbl = lane_ranks(world, n_lanes);
                let mut seen = vec![false; world];
                for (l, ranks) in tbl.iter().enumerate() {
                    for &r in ranks {
                        assert_eq!(lane_of_rank(r, n_lanes), l);
                        assert!(!seen[r], "rank {r} on two lanes");
                        seen[r] = true;
                    }
                }
                assert!(seen.into_iter().all(|s| s), "rank dropped");
                assert_eq!(tbl[0][0], 0, "rank 0 must ride lane 0");
            }
        }
    }

    /// Rebuilding a smaller graph into the same dag reuses slots; both
    /// runs complete every node exactly once.
    #[test]
    fn rebuild_reuses_slots() {
        let mut dag: TaskDag<usize> = TaskDag::new();
        for (n, lanes) in [(12usize, 2usize), (5, 1), (12, 2)] {
            dag.begin(lanes);
            let count = AtomicU64::new(0);
            for i in 0..n {
                dag.add(i, (i < lanes).then_some(i));
            }
            dag.run::<(), _, _>(
                lanes.max(2),
                |_, _| {
                    count.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                |_| panic!("no failures expected"),
            );
            assert_eq!(count.load(Ordering::SeqCst), n as u64);
            assert_eq!(dag.node_count(), n);
        }
    }
}
