//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! step path. Also hosts the runtime-JIT Newton–Schulz fast path
//! (`ns_builder`) used for shard shapes that have no Pallas artifact.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits 64-bit instruction ids in
//! serialized protos which xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` reassigns ids (see aot.py).

pub mod artifact;
pub mod dag;
pub mod ns_builder;
pub mod ns_engine;
pub mod pool;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use crate::tensor::Tensor;

pub use artifact::{ConfigEntry, Manifest, ParamEntry};
pub use dag::{lane_of_rank, lane_ranks, DagFailure, Severity, TaskDag};
pub use ns_engine::NsEngine;
pub use pool::{Pool, WorkerArena};

/// Convert a host tensor to an f32 XLA literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(
            t.data().as_ptr() as *const u8,
            t.data().len() * 4,
        )
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        t.shape(),
        bytes,
    )?)
}

/// Convert an i32 token batch to an XLA literal of shape [rows, cols].
pub fn tokens_to_literal(tokens: &[i32], rows: usize, cols: usize) -> Result<Literal> {
    anyhow::ensure!(tokens.len() == rows * cols, "token shape mismatch");
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(
            tokens.as_ptr() as *const u8,
            tokens.len() * 4,
        )
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        &[rows, cols],
        bytes,
    )?)
}

/// Convert an f32 XLA literal back to a host tensor with the given shape.
pub fn literal_to_tensor(lit: &Literal, shape: &[usize]) -> Result<Tensor> {
    let v = lit.to_vec::<f32>()?;
    Tensor::from_vec(shape, v)
}

/// A compiled artifact plus its output shapes.
pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal args; returns the decomposed result tuple
    /// (aot.py lowers everything with return_tuple=True).
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let out = self
            .exe
            .execute::<Literal>(args)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// The PJRT runtime: one CPU client + the artifact registry.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifact directory (built by `make artifacts`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "loading manifest from {} (run `make artifacts`)",
                    dir.display()
                )
            })?;
        let client = PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest })
    }

    /// Locate the artifact dir relative to the repo root (works from
    /// examples, benches, and tests).
    pub fn open_default() -> Result<Runtime> {
        let candidates = ["artifacts", "../artifacts", "../../artifacts"];
        for c in candidates {
            if Path::new(c).join("manifest.json").exists() {
                return Runtime::open(c);
            }
        }
        // CARGO_MANIFEST_DIR fallback for cargo test/bench cwd quirks.
        let base = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if base.join("manifest.json").exists() {
            return Runtime::open(base);
        }
        Err(anyhow!("artifacts/manifest.json not found; run `make artifacts`"))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Compile an HLO-text artifact by file name.
    pub fn compile_artifact(&self, file: &str) -> Result<Executable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe, name: file.to_string() })
    }

    /// Compile the train-step executable for a model config.
    pub fn train_step(&self, config: &str) -> Result<Executable> {
        let entry = self.manifest.config(config)?;
        self.compile_artifact(&entry.train_hlo)
    }

    /// Compile the eval-step executable for a model config.
    pub fn eval_step(&self, config: &str) -> Result<Executable> {
        let entry = self.manifest.config(config)?;
        self.compile_artifact(&entry.eval_hlo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.])
            .unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit, &[2, 3]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tokens_literal_shape() {
        let lit = tokens_to_literal(&[1, 2, 3, 4, 5, 6], 2, 3).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert!(tokens_to_literal(&[1, 2], 2, 3).is_err());
    }
}
