//! NsEngine — the orthogonalization service used on the optimizer hot path.
//!
//! Resolution order per shape:
//! 1. **Pallas artifact** (`artifacts/ns_MxN.hlo.txt`): the L1 kernel AOT'd
//!    by python; proves the three-layer path end to end.
//! 2. **Runtime XLA JIT** (`ns_builder`): same math composed with
//!    XlaBuilder and compiled once per shape — covers arbitrary shard
//!    shapes with XLA-grade GEMMs.
//! 3. **Host Newton–Schulz** (`linalg`): pure-rust fallback (also used when
//!    no PJRT client is wanted, e.g. small unit tests). This path runs the
//!    fused `NsWorkspace` kernels — packed MC/KC-blocked GEMM + symmetric
//!    syrk with per-thread buffer arenas, large iterations fanning row
//!    blocks across the persistent worker pool (`runtime::pool`) — so
//!    "fallback" no longer means "slow": after the first call on a thread
//!    the K-iteration loop is allocation-free, register-tiled and
//!    multicore.
//!
//! Compiled executables are cached per shape. All XLA state lives behind
//! one mutex so the rank threads of the simulated cluster share the engine:
//! the `xla` crate's handles use non-atomic `Rc` refcounts internally, so
//! we serialize *every* access (clone/execute/drop) through the lock and
//! assert Send/Sync manually — sound because no XLA handle ever escapes the
//! lock, and the underlying PJRT CPU client is itself thread-safe.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;
use xla::PjRtLoadedExecutable;

use crate::linalg::newton_schulz::{newton_schulz, NsCoeffs};
use crate::optim::muon::OrthFn;
use crate::runtime::{literal_to_tensor, ns_builder, tensor_to_literal, Runtime};
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NsBackendKind {
    PallasArtifact,
    RuntimeJit,
    Host,
}

struct XlaState {
    runtime: Option<Arc<Runtime>>,
    exes: HashMap<(usize, usize), (PjRtLoadedExecutable, NsBackendKind)>,
    hits: u64,
    misses: u64,
}

// SAFETY: XlaState only moves between threads inside NsEngine's mutex (see
// module docs); the PJRT CPU runtime is internally synchronized and the
// non-atomic Rc refcounts are never touched concurrently because every
// clone/execute/drop happens under the lock.
unsafe impl Send for XlaState {}

/// Shape-cached orthogonalizer.
pub struct NsEngine {
    state: Mutex<XlaState>,
    pub steps: usize,
    pub coeffs: NsCoeffs,
    /// Disable the XLA paths entirely (host-only mode).
    pub host_only: bool,
}

// SAFETY: all interior XLA access is serialized by `state`'s mutex.
unsafe impl Sync for NsEngine {}

impl NsEngine {
    pub fn new(runtime: Option<Arc<Runtime>>) -> NsEngine {
        NsEngine {
            state: Mutex::new(XlaState {
                runtime,
                exes: HashMap::new(),
                hits: 0,
                misses: 0,
            }),
            steps: 5,
            coeffs: NsCoeffs::jordan(),
            host_only: false,
        }
    }

    pub fn host_only() -> NsEngine {
        let mut e = NsEngine::new(None);
        e.host_only = true;
        e
    }

    /// (hits, misses) of the executable cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        let c = self.state.lock().unwrap();
        (c.hits, c.misses)
    }

    /// Which backend serves the given shape.
    pub fn backend_for(&self, m: usize, n: usize) -> NsBackendKind {
        let st = self.state.lock().unwrap();
        if self.host_only || st.runtime.is_none() {
            return NsBackendKind::Host;
        }
        let rt = st.runtime.as_ref().unwrap();
        if rt.manifest.ns_kernel(m, n).is_some() {
            NsBackendKind::PallasArtifact
        } else {
            NsBackendKind::RuntimeJit
        }
    }

    /// Orthogonalize `g` (≈ polar factor) through the best available path.
    /// Host paths use the calling thread's `NsWorkspace` (zero-alloc fused
    /// NS loop) via `linalg::newton_schulz`.
    pub fn orthogonalize(&self, g: &Tensor) -> Result<Tensor> {
        let (m, n) = (g.m(), g.n());
        if self.host_only {
            return Ok(newton_schulz(g, self.steps, self.coeffs));
        }
        let mut st = self.state.lock().unwrap();
        if st.runtime.is_none() {
            return Ok(newton_schulz(g, self.steps, self.coeffs));
        }
        if !st.exes.contains_key(&(m, n)) {
            st.misses += 1;
            let rt = Arc::clone(st.runtime.as_ref().unwrap());
            let entry = match rt.manifest.ns_kernel(m, n) {
                Some(k) => (
                    rt.compile_artifact(&k.hlo)?.into_inner(),
                    NsBackendKind::PallasArtifact,
                ),
                None => (
                    ns_builder::compile_ns(
                        rt.client(),
                        m,
                        n,
                        self.steps,
                        self.coeffs,
                    )?,
                    NsBackendKind::RuntimeJit,
                ),
            };
            st.exes.insert((m, n), entry);
        } else {
            st.hits += 1;
        }
        let (exe, kind) = st.exes.get(&(m, n)).unwrap();
        let lit = tensor_to_literal(g)?;
        let out =
            exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // Pallas artifacts were lowered with return_tuple=True; the runtime
        // JIT builds a bare array computation.
        let arr = match kind {
            NsBackendKind::PallasArtifact => {
                let mut parts = out.to_tuple()?;
                anyhow::ensure!(parts.len() == 1, "ns artifact arity");
                parts.remove(0)
            }
            _ => out,
        };
        literal_to_tensor(&arr, &[m, n])
    }

    /// Wrap as the `OrthFn` callback the Muon family accepts. Falls back to
    /// host NS on execution error (never poisons a training step).
    pub fn as_orth_fn(self: &Arc<Self>) -> OrthFn {
        let me = Arc::clone(self);
        Arc::new(move |g: &Tensor| {
            me.orthogonalize(g)
                .unwrap_or_else(|_| newton_schulz(g, me.steps, me.coeffs))
        })
    }
}

impl crate::runtime::Executable {
    /// Extract the raw loaded executable (NsEngine cache storage).
    pub fn into_inner(self) -> PjRtLoadedExecutable {
        self.exe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::rng::Rng;

    #[test]
    fn host_only_matches_linalg() {
        let e = NsEngine::host_only();
        let mut rng = Rng::new(1);
        let g = Tensor::randn(&[8, 24], 1.0, &mut rng);
        let a = e.orthogonalize(&g).unwrap();
        let b = newton_schulz(&g, 5, NsCoeffs::jordan());
        assert_eq!(a, b);
        assert_eq!(e.backend_for(8, 24), NsBackendKind::Host);
    }

    #[test]
    fn orth_fn_callback_works() {
        let e = Arc::new(NsEngine::host_only());
        let f = e.as_orth_fn();
        let mut rng = Rng::new(2);
        let g = Tensor::randn(&[4, 12], 1.0, &mut rng);
        let u = f(&g);
        assert_eq!(u.shape(), g.shape());
    }
}
