//! Persistent worker pool with per-worker arenas — the process-wide
//! threading substrate of the host hot path.
//!
//! Before this module, four sites re-spawned `thread::scope` threads on
//! every call: `gemm_into` row panels, `Muon::orth_update_with` block
//! fan-out, and the coordinator's DP/TP rank threads (now the phased
//! `DistMuon::step` — DP collectives via [`Pool::run_concurrent`], TP
//! rank work via [`Pool::fanout`]).
//! Each spawn re-warmed a fresh thread-local `NsWorkspace`, so the
//! zero-alloc property held only *within* one call, and full-step
//! Newton–Schulz could never thread its inner GEMMs (scoped spawns inside
//! the K-loop would allocate every iteration). The pool fixes both:
//!
//! - **Long-lived parked workers**, created once ([`Pool::global`]), each
//!   owning a preallocated [`WorkerArena`] (`NsWorkspace` + GEMM packing
//!   scratch) that stays warm across optimizer steps. The pooled GEMM
//!   packs each row block's A panels in the owning worker's arena, so
//!   per-worker pack scratch tops out at one MC×k panel set (see the
//!   `WorkerArena::pa` docs) and packing itself runs in parallel.
//! - **Allocation-free dispatch**: a fan-out publishes one type-erased
//!   `(data, trampoline)` pointer pair under a mutex and wakes the workers;
//!   no boxing, no channels, no per-task heap traffic. After pool warm-up,
//!   `fanout` performs zero heap allocations, which is what lets
//!   `NsWorkspace::iterate` go multicore while `tests/ns_zero_alloc.rs`
//!   still proves the steady state allocation-free across whole
//!   `Muon::step` calls.
//! - **Deterministic results**: task `i` of a fan-out always computes the
//!   same values regardless of worker count or scheduling, because tasks
//!   partition the output disjointly and each task runs the same sequential
//!   kernel. Every pooled path is bit-identical to its sequential
//!   counterpart (see `tests/pool_stress.rs` and the determinism tests in
//!   `gemm`/`muon`).
//!
//! # Nesting contract
//!
//! Pool parallelism lives at the *outermost* dispatch only. A [`Pool::fanout`]
//! issued from inside a pool worker runs inline (sequentially, on that
//! worker) — same results, no deadlock. [`Pool::run_concurrent_map`] /
//! [`Pool::run_concurrent`] tasks are allowed to rendezvous with each
//! other (collective phases), so a nested call falls back to freshly
//! scoped threads instead of inlining.
//!
//! # Shutdown
//!
//! The global pool lives for the process. Locally constructed pools
//! ([`Pool::new`]) join all workers on drop; dropping a pool with no job in
//! flight is always safe because submissions hold `&self`.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

use crate::linalg::newton_schulz::NsWorkspace;

/// Per-worker scratch arena: everything a task may need, preallocated once
/// per worker and reused for every job the worker ever runs. Constructing
/// one allocates nothing (all buffers are grow-only and start empty); the
/// first tasks a worker runs warm it to the high-water mark.
pub struct WorkerArena {
    /// Newton–Schulz ping-pong arena (block orthogonalizations).
    pub ns: NsWorkspace,
    /// GEMM A-panel packing scratch. The pooled `gemm_into`/`syrk_into`
    /// row-block fan-out packs each MC row block's A panels *in the
    /// worker that owns the block* (parallel packing), so the high-water
    /// size is one MC × k panel set — `MC · k_max` floats padded to the
    /// dispatched microkernel's `mr` — rather than all of A. The shared
    /// packed B (NC×KC panel groups, padded to `nr`) is packed once by
    /// the submitting thread and read-only from workers.
    pub pa: Vec<f32>,
}

impl WorkerArena {
    pub fn new() -> WorkerArena {
        WorkerArena { ns: NsWorkspace::new(), pa: Vec::new() }
    }
}

impl Default for WorkerArena {
    fn default() -> Self {
        WorkerArena::new()
    }
}

/// Copyable raw-pointer wrapper for fan-out tasks that write disjoint
/// regions of one buffer (row panels of a GEMM output, per-block update
/// slots). The caller asserts disjointness; the wrapper only supplies the
/// Send/Sync the closure needs to cross into the workers.
pub struct SendPtr<T>(pub *mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
// SAFETY: SendPtr is a plain address; the pool's fan-out contract (each
// task writes only its own disjoint region, all tasks joined before the
// submitting call returns) is what makes dereferences sound.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// One published fan-out: a type-erased pointer to the submitting call's
/// closure plus its monomorphized trampoline. `Copy` so workers can take it
/// out of the slot without touching the heap.
#[derive(Clone, Copy)]
struct JobRef {
    /// `&F` of the submitting `fanout` call, erased. Valid until that call
    /// returns, which cannot happen before every participating worker has
    /// checked in.
    data: *const (),
    call: unsafe fn(*const (), usize, &mut WorkerArena),
    ntasks: usize,
    /// Workers participating in this job: worker `w < workers` runs tasks
    /// `w, w + workers, w + 2·workers, …` (static strided assignment — no
    /// shared claim counter a straggler from a previous job could race).
    workers: usize,
}

// SAFETY: `data` is only dereferenced through `call`, whose `F: Sync`
// bound makes the shared borrow valid from worker threads; lifetime is
// enforced by the submit/check-in protocol described on `JobRef::data`.
unsafe impl Send for JobRef {}

unsafe fn trampoline<F: Fn(usize, &mut WorkerArena) + Sync>(
    data: *const (),
    task: usize,
    arena: &mut WorkerArena,
) {
    let f = &*(data as *const F);
    f(task, arena);
}

struct Slot {
    /// Bumped once per published job; workers participate in a job exactly
    /// once by comparing against the last epoch they served.
    epoch: u64,
    job: Option<JobRef>,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    /// Workers wait here for a new epoch.
    work_cv: Condvar,
    /// The submitter waits here for `pending == 0`.
    done_cv: Condvar,
    /// Participating workers yet to check in for the current job.
    pending: AtomicUsize,
    /// Set when any task panicked; the submitter re-raises after the join.
    panicked: AtomicBool,
}

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
    /// Arena used when a fan-out runs inline on the submitting thread
    /// (small jobs, single-worker pools, or nested dispatch).
    static INLINE_ARENA: RefCell<WorkerArena> = RefCell::new(WorkerArena::new());
}

/// True on threads owned by a [`Pool`] — nested fan-outs from such threads
/// run inline rather than re-entering the pool.
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

fn worker_main(shared: Arc<Shared>, index: usize) {
    IN_POOL_WORKER.with(|c| c.set(true));
    let mut arena = WorkerArena::new();
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != last_epoch {
                    last_epoch = slot.epoch;
                    if let Some(job) = slot.job {
                        break job;
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        if index >= job.workers {
            // Not a participant of this job; `pending` did not count us.
            continue;
        }
        let mut t = index;
        while t < job.ntasks {
            // SAFETY: see `JobRef::data` — the closure outlives the job.
            let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || unsafe { (job.call)(job.data, t, &mut arena) },
            ));
            if run.is_err() {
                // The default panic hook already printed the payload;
                // remember it so the submitter can re-raise after the join
                // instead of deadlocking on a missing check-in.
                shared.panicked.store(true, Ordering::Release);
            }
            t += job.workers;
        }
        if shared.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last check-in: take the lock so the notify cannot land
            // between the submitter's predicate check and its wait.
            let _g = shared.slot.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

/// Persistent worker pool. See the module docs for the threading and
/// determinism contract.
pub struct Pool {
    shared: Arc<Shared>,
    /// Serializes fan-outs: one job in flight at a time, so concurrent
    /// submitters queue here (results stay bit-identical under contention).
    submit: Mutex<()>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    size: AtomicUsize,
    /// Whether `run_concurrent_map` may spawn extra workers on demand.
    /// Pinned to `false` when the operator fixed the size via
    /// `MUONBP_POOL_THREADS` — rendezvous phases then use scoped threads
    /// instead of silently re-enabling pooled parallelism.
    growable: bool,
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// Bad `MUONBP_POOL_THREADS` configuration: carries the offending value so
/// the launcher can report exactly what the operator set, instead of the
/// `panic!` this used to be.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolConfigError {
    /// The raw value (lossily decoded when not valid unicode).
    pub value: String,
    pub reason: String,
}

impl std::fmt::Display for PoolConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MUONBP_POOL_THREADS={:?}: {} (want a thread count, e.g. 8; \
             0 or 1 disables pooled parallelism)",
            self.value, self.reason
        )
    }
}

impl std::error::Error for PoolConfigError {}

/// Parse a raw `MUONBP_POOL_THREADS` lookup result: `Ok(None)` when the
/// variable is unset (use the per-core default), `Ok(Some(n))` for an
/// explicit pin, `Err` — with the offending value — when it is set but
/// unreadable or not a number.
fn parse_pool_threads(
    raw: Result<String, std::env::VarError>,
) -> Result<Option<usize>, PoolConfigError> {
    match raw {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) => Ok(Some(n)),
            Err(e) => Err(PoolConfigError {
                value: v,
                reason: format!("not a thread count ({e})"),
            }),
        },
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(std::env::VarError::NotUnicode(os)) => Err(PoolConfigError {
            value: os.to_string_lossy().into_owned(),
            reason: "not valid unicode".into(),
        }),
    }
}

impl Pool {
    /// Pool with `workers` persistent threads (fewer if spawning fails);
    /// may grow on demand for rendezvous fan-outs.
    pub fn new(workers: usize) -> Pool {
        Pool::build(workers, true)
    }

    fn build(workers: usize, growable: bool) -> Pool {
        let pool = Pool {
            shared: Arc::new(Shared {
                slot: Mutex::new(Slot {
                    epoch: 0,
                    job: None,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                pending: AtomicUsize::new(0),
                panicked: AtomicBool::new(false),
            }),
            submit: Mutex::new(()),
            handles: Mutex::new(Vec::new()),
            size: AtomicUsize::new(0),
            growable,
        };
        pool.spawn_workers(workers);
        pool
    }

    /// The process-wide pool every hot path routes through. Created on
    /// first use with one worker per available core. `MUONBP_POOL_THREADS`
    /// pins the size instead (`0` or `1` disables pooled parallelism —
    /// every fan-out then runs inline or on throwaway scoped threads,
    /// still bit-identical — and a pinned pool never grows).
    ///
    /// A malformed pin panics here; launchers should preflight with
    /// [`Pool::try_global`] to turn that into a reportable configuration
    /// error before any hot path runs.
    pub fn global() -> &'static Pool {
        Pool::try_global().unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Pool::global`] that surfaces a bad `MUONBP_POOL_THREADS` as a
    /// structured [`PoolConfigError`] (with the offending value) instead
    /// of panicking. The env var is parsed *before* the pool is
    /// instantiated, so a rejected configuration leaves no half-built
    /// global behind.
    pub fn try_global() -> Result<&'static Pool, PoolConfigError> {
        if let Some(pool) = GLOBAL.get() {
            return Ok(pool);
        }
        // A pin the operator set must be honored or rejected loudly —
        // silently falling back to a growable per-core pool would
        // re-enable exactly the parallelism the pin disables.
        let pinned = parse_pool_threads(std::env::var("MUONBP_POOL_THREADS"))?;
        Ok(GLOBAL.get_or_init(|| match pinned {
            Some(n) => Pool::build(n, false),
            None => Pool::build(
                thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
                true,
            ),
        }))
    }

    /// Number of live workers.
    pub fn workers(&self) -> usize {
        self.size.load(Ordering::Acquire)
    }

    /// Worker count a *compute* fan-out (GEMM/syrk row blocks, block
    /// orthogonalizations) should budget for. Operator-pinned pools
    /// (`MUONBP_POOL_THREADS`) return the pinned size — an explicit
    /// instruction. Growable pools return the live size capped at the
    /// core count: rendezvous phases may grow the pool past the cores
    /// because collective tasks mostly block, but those extra workers
    /// add no compute throughput — fanning row blocks across them would
    /// only thrash caches and context-switch. Allocation-free after the
    /// first call (the core count is cached; on Linux
    /// `available_parallelism` re-reads /proc and heap-allocates per
    /// call, which the zero-alloc proof would see).
    pub fn compute_workers(&self) -> usize {
        let w = self.workers();
        if !self.growable {
            return w;
        }
        w.min(cached_cores())
    }

    /// [`Pool::compute_workers`] of the global pool *if it exists*, else
    /// the core count a default pool would be built with. A pure sizing
    /// query: it never instantiates the pool, so library consumers that
    /// ask for a thread budget but never actually fan out (single
    /// row-block products) don't pay for N parked worker threads. Before
    /// the pool exists a `MUONBP_POOL_THREADS` pin is not visible here —
    /// harmless, because every fan-out is capped by the real pool at
    /// dispatch time and results are thread-count-invariant anyway.
    pub fn global_compute_width() -> usize {
        match GLOBAL.get() {
            Some(p) => p.compute_workers(),
            None => cached_cores(),
        }
    }

    fn spawn_workers(&self, total: usize) {
        let mut handles = self.handles.lock().unwrap();
        let cur = self.size.load(Ordering::Acquire);
        for i in cur..total {
            let shared = Arc::clone(&self.shared);
            let builder =
                thread::Builder::new().name(format!("muonbp-pool-{i}"));
            match builder.spawn(move || worker_main(shared, i)) {
                Ok(h) => {
                    handles.push(h);
                    self.size.fetch_add(1, Ordering::Release);
                }
                Err(_) => break,
            }
        }
    }

    /// Grow to at least `n` workers (no job may be in flight while workers
    /// join, hence the submit lock). Returns whether `n` are available.
    /// Size-pinned pools (`MUONBP_POOL_THREADS`) never grow — callers fall
    /// back to scoped threads.
    fn try_ensure_workers(&self, n: usize) -> bool {
        if self.workers() >= n {
            return true;
        }
        if !self.growable {
            return false;
        }
        {
            let _guard = self.submit.lock().unwrap();
            self.spawn_workers(n);
        }
        self.workers() >= n
    }

    /// Fan `ntasks` independent tasks out across the pool and join them.
    /// Task `i` receives `(i, &mut arena)`; tasks must write disjoint
    /// outputs. Results are bit-identical to running tasks `0..ntasks`
    /// sequentially, for any pool size — including zero (inline fallback).
    /// Allocation-free after pool warm-up.
    pub fn fanout<F>(&self, ntasks: usize, f: F)
    where
        F: Fn(usize, &mut WorkerArena) + Sync,
    {
        self.fanout_limited(ntasks, usize::MAX, &f);
    }

    /// [`Pool::fanout`] with an upper bound on participating workers
    /// (kernels pass their FLOP-derived thread budget here).
    pub fn fanout_limited<F>(&self, ntasks: usize, max_workers: usize, f: &F)
    where
        F: Fn(usize, &mut WorkerArena) + Sync,
    {
        if ntasks == 0 {
            return;
        }
        let workers = self.workers().min(max_workers).min(ntasks);
        if workers <= 1 || in_pool_worker() {
            run_inline(ntasks, f);
            return;
        }
        self.dispatch(ntasks, workers, f);
    }

    /// Run `n` tasks that may rendezvous with each other (collective
    /// phases): every task is guaranteed its own concurrently running
    /// thread. Task `i` always lands on worker `i`, so a rank keeps the
    /// same warm thread-local state across steps. Grows the pool beyond
    /// the core count if `n` demands it (rendezvous tasks mostly block)
    /// unless the size was pinned via `MUONBP_POOL_THREADS`. Falls back to
    /// freshly scoped threads — marked as pool workers, so their nested
    /// fan-outs inline — when called from inside a pool worker, when the
    /// pool is size-pinned below `n`, or when workers cannot be spawned.
    pub fn run_concurrent_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut WorkerArena) -> T + Sync,
    {
        // Thin wrapper over run_concurrent: same concurrency/fallback
        // rules, plus per-task result slots written through disjoint
        // SendPtr offsets.
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let slots = SendPtr(out.as_mut_ptr());
        self.run_concurrent(n, |i, arena| {
            let v = f(i, arena);
            // SAFETY: task i writes slot i exactly once; slots are
            // disjoint and `out` outlives the join inside run_concurrent.
            unsafe { *slots.0.add(i) = Some(v) };
        });
        out.into_iter()
            .map(|o| o.expect("pool: task produced no result"))
            .collect()
    }

    /// [`Pool::run_concurrent_map`] for tasks with no result: the same
    /// concurrency guarantee (task `i` pinned to worker `i`, every task
    /// live simultaneously, tasks may rendezvous with each other) without
    /// the result-slot vector — in the steady state a call performs zero
    /// heap allocations, since dispatch is pointer publication only. The
    /// phased coordinator runs its DP collective phase through this every
    /// step, which is part of what lets a warm `DistMuon::step` allocate
    /// nothing. Falls back to freshly scoped threads under the same
    /// conditions as `run_concurrent_map` (nested caller, size-pinned or
    /// degraded pool).
    pub fn run_concurrent<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, &mut WorkerArena) + Sync,
    {
        if n == 0 {
            return;
        }
        if n == 1 {
            run_inline(1, &f);
        } else if in_pool_worker() || !self.try_ensure_workers(n) {
            // Rendezvous tasks must not be serialized (they would deadlock
            // waiting for each other), so the nested / size-pinned /
            // degraded path spawns real scoped threads instead of
            // inlining. The spawned threads are marked as pool workers so
            // any fan-out they issue runs inline rather than re-entering
            // the pool — a nested dispatch would block on the submit lock
            // an enclosing fan-out may already hold (deadlock).
            thread::scope(|s| {
                for i in 0..n {
                    let f = &f;
                    s.spawn(move || {
                        IN_POOL_WORKER.with(|c| c.set(true));
                        let mut arena = WorkerArena::new();
                        f(i, &mut arena);
                    });
                }
            });
        } else {
            self.dispatch(n, n, &f);
        }
    }

    fn dispatch<F>(&self, ntasks: usize, workers: usize, f: &F)
    where
        F: Fn(usize, &mut WorkerArena) + Sync,
    {
        let job = JobRef {
            data: f as *const F as *const (),
            call: trampoline::<F>,
            ntasks,
            workers,
        };
        let guard = self.submit.lock().unwrap();
        self.shared.pending.store(workers, Ordering::Release);
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.epoch = slot.epoch.wrapping_add(1);
            slot.job = Some(job);
            self.shared.work_cv.notify_all();
        }
        let mut slot = self.shared.slot.lock().unwrap();
        while self.shared.pending.load(Ordering::Acquire) != 0 {
            slot = self.shared.done_cv.wait(slot).unwrap();
        }
        slot.job = None;
        drop(slot);
        let panicked = self.shared.panicked.swap(false, Ordering::AcqRel);
        drop(guard);
        if panicked {
            panic!("pool: a fan-out task panicked (see stderr for payload)");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.get_mut().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Cached `available_parallelism` (see [`Pool::compute_workers`]).
fn cached_cores() -> usize {
    static CORES: AtomicUsize = AtomicUsize::new(0);
    match CORES.load(Ordering::Relaxed) {
        0 => {
            let n = thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            CORES.store(n, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

fn run_inline<F>(ntasks: usize, f: &F)
where
    F: Fn(usize, &mut WorkerArena) + Sync,
{
    INLINE_ARENA.with(|cell| match cell.try_borrow_mut() {
        Ok(mut arena) => {
            for t in 0..ntasks {
                f(t, &mut arena);
            }
        }
        Err(_) => {
            // Re-entrant inline fan-out (a task dispatched inline spawned
            // another): a fresh arena keeps it correct, and constructing
            // one is allocation-free (grow-only buffers start empty).
            let mut arena = WorkerArena::new();
            for t in 0..ntasks {
                f(t, &mut arena);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn fanout_runs_every_task_once() {
        let pool = Pool::new(3);
        let hits: Vec<AtomicU64> =
            (0..17).map(|_| AtomicU64::new(0)).collect();
        pool.fanout(17, |i, _| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn fanout_disjoint_writes_via_sendptr() {
        let pool = Pool::new(2);
        let mut out = vec![0u64; 100];
        let ptr = SendPtr(out.as_mut_ptr());
        pool.fanout(100, |i, _| unsafe {
            *ptr.0.add(i) = (i * i) as u64;
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i * i) as u64);
        }
    }

    #[test]
    fn inline_when_empty_or_single() {
        for workers in [0, 1] {
            let pool = Pool::new(workers);
            let mut out = vec![0usize; 9];
            let ptr = SendPtr(out.as_mut_ptr());
            pool.fanout(9, |i, _| unsafe {
                *ptr.0.add(i) = i + 1;
            });
            assert_eq!(out, (1..=9).collect::<Vec<_>>());
        }
    }

    #[test]
    fn nested_fanout_runs_inline() {
        let pool = Pool::new(2);
        let depth_hits = AtomicU64::new(0);
        pool.fanout(2, |_, _| {
            // Nested dispatch from a worker must complete inline.
            Pool::global().fanout(3, |_, _| {
                depth_hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(depth_hits.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn run_concurrent_map_rendezvous() {
        // Tasks barrier on each other: only true concurrency finishes this.
        let pool = Pool::new(2);
        let n = 4; // forces growth beyond the initial 2 workers
        let arrived = AtomicUsize::new(0);
        let got = pool.run_concurrent_map(n, |i, _| {
            arrived.fetch_add(1, Ordering::SeqCst);
            while arrived.load(Ordering::SeqCst) < n {
                std::thread::yield_now();
            }
            i * 10
        });
        assert_eq!(got, vec![0, 10, 20, 30]);
        assert!(pool.workers() >= n);
    }

    #[test]
    fn worker_arena_persists_across_jobs() {
        let pool = Pool::new(1);
        // Job 1 warms the arena; job 2 observes the warm buffers. With a
        // single worker both jobs land on the same arena... unless the
        // fan-out inlines (1 worker => inline on the submitter), which
        // exercises the same persistence through INLINE_ARENA.
        pool.fanout(1, |_, arena| {
            arena.pa.resize(1024, 1.0);
        });
        let mut saw = 0usize;
        let saw_ptr = SendPtr(&mut saw as *mut usize);
        pool.fanout(1, |_, arena| unsafe {
            *saw_ptr.0 = arena.pa.len();
        });
        assert_eq!(saw, 1024);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = Pool::new(4);
        let mut out = vec![0u8; 4];
        let ptr = SendPtr(out.as_mut_ptr());
        pool.fanout(4, |i, _| unsafe {
            *ptr.0.add(i) = 1;
        });
        drop(pool); // must not hang or leak panics
        assert_eq!(out, vec![1; 4]);
    }

    #[test]
    fn parse_pool_threads_accepts_counts_and_absence() {
        assert_eq!(parse_pool_threads(Ok("8".into())), Ok(Some(8)));
        assert_eq!(parse_pool_threads(Ok(" 0 ".into())), Ok(Some(0)));
        assert_eq!(
            parse_pool_threads(Err(std::env::VarError::NotPresent)),
            Ok(None)
        );
    }

    #[test]
    fn parse_pool_threads_reports_offending_value() {
        let err = parse_pool_threads(Ok("lots".into())).unwrap_err();
        assert_eq!(err.value, "lots");
        let msg = err.to_string();
        assert!(msg.contains("lots"), "message must name the value: {msg}");
        assert!(msg.contains("MUONBP_POOL_THREADS"));

        let err = parse_pool_threads(Ok("-3".into())).unwrap_err();
        assert_eq!(err.value, "-3");

        #[cfg(unix)]
        {
            use std::os::unix::ffi::OsStringExt;
            let os = std::ffi::OsString::from_vec(vec![b'a', 0xff, b'b']);
            let err = parse_pool_threads(Err(
                std::env::VarError::NotUnicode(os),
            ))
            .unwrap_err();
            assert!(err.reason.contains("unicode"));
            assert!(err.value.contains('a') && err.value.contains('b'));
        }
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let pool = Pool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.fanout(4, |i, _| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // The pool stays usable after a task panic.
        let mut out = vec![0usize; 3];
        let ptr = SendPtr(out.as_mut_ptr());
        pool.fanout(3, |i, _| unsafe {
            *ptr.0.add(i) = i + 7;
        });
        assert_eq!(out, vec![7, 8, 9]);
    }
}
