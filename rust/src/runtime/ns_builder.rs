//! Runtime-JIT Newton–Schulz: compose the NS orthogonalization directly
//! with `XlaBuilder` and compile it on the PJRT CPU client for any shape.
//!
//! This is the L3 fast path when a shard shape has no Pallas artifact:
//! identical math to `linalg::newton_schulz` / the L1 kernel, but executed
//! through XLA's optimized GEMMs instead of the host matmul. No python is
//! involved — the computation is built op-by-op in rust.

use anyhow::Result;
use xla::{ElementType, PjRtClient, PjRtLoadedExecutable, XlaBuilder};

use crate::linalg::newton_schulz::NsCoeffs;

/// Build and compile `orth(G)` for a fixed (m, n) shape.
pub fn compile_ns(
    client: &PjRtClient,
    m: usize,
    n: usize,
    steps: usize,
    coeffs: NsCoeffs,
) -> Result<PjRtLoadedExecutable> {
    let builder = XlaBuilder::new(&format!("ns_{m}x{n}"));
    let g = builder.parameter(
        0,
        ElementType::F32,
        &[m as i64, n as i64],
        "g",
    )?;

    // Work on the wide orientation (rows <= cols) like the kernel does.
    let transpose = m > n;
    let mut x = if transpose { g.transpose(&[1, 0])? } else { g };

    // X <- G / (||G||_F + eps)
    let sq = x.mul_(&x)?;
    let norm = sq.reduce_sum(&[0, 1], false)?.sqrt()?;
    let eps = builder.constant_r0(1e-7f32)?;
    let denom = norm.add_(&eps)?;
    x = x.div_(&denom.broadcast(&[])?)?;

    let ca = builder.constant_r0(coeffs.a)?;
    let cb = builder.constant_r0(coeffs.b)?;
    let cc = builder.constant_r0(coeffs.c)?;
    for _ in 0..steps {
        let xt = x.transpose(&[1, 0])?;
        let gram = x.matmul(&xt)?; // A = X Xᵀ
        let gram2 = gram.matmul(&gram)?; // A²
        let poly = gram.mul_(&cb)?.add_(&gram2.mul_(&cc)?)?; // bA + cA²
        x = x.mul_(&ca)?.add_(&poly.matmul(&x)?)?; // aX + BX
    }
    let out = if transpose { x.transpose(&[1, 0])? } else { x };
    let comp = out.build()?;
    Ok(client.compile(&comp)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::newton_schulz::newton_schulz;
    use crate::runtime::{literal_to_tensor, tensor_to_literal};
    use crate::tensor::Tensor;
    use crate::utils::rng::Rng;

    fn run_ns(m: usize, n: usize) {
        let client = PjRtClient::cpu().unwrap();
        let exe = compile_ns(&client, m, n, 5, NsCoeffs::jordan()).unwrap();
        let mut rng = Rng::new(42);
        let g = Tensor::randn(&[m, n], 1.0, &mut rng);
        let lit = tensor_to_literal(&g).unwrap();
        let out = exe.execute::<xla::Literal>(&[lit]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let got = literal_to_tensor(&out, &[m, n]).unwrap();
        let want = newton_schulz(&g, 5, NsCoeffs::jordan());
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b} ({m}x{n})");
        }
    }

    #[test]
    fn matches_host_ns_wide() {
        run_ns(16, 48);
    }

    #[test]
    fn matches_host_ns_tall() {
        run_ns(48, 16);
    }

    #[test]
    fn matches_host_ns_square() {
        run_ns(32, 32);
    }
}
